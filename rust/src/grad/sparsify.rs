//! Gradient sparsification — the §4.4 BASELINE the paper evaluates and
//! rejects (Wangni et al. [43]).
//!
//! Top-k magnitude sparsification with local error feedback
//! (accumulating the dropped residual, as the sparsification literature
//! prescribes).  The paper's argument against it for BERT:
//! (a) the gradients are dense (Fig. 4 — attention/intermediate/output
//! matmuls), so aggressive thresholds distort the signal;
//! (b) threshold selection costs compute and tuning.
//! The `sec44_sparsification` bench quantifies both effects on real
//! BERT gradients from the PJRT substrate.

use crate::util::Pcg64;

/// What the collective pool ships over **network-crossing** ring links
/// (`train.sparsify`): dense payloads, or the top-k magnitude subset
/// with local error feedback.  PCIe-class intra-node links always stay
/// dense — the paper places lossy compression on the slow fabric only.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Sparsify {
    /// Dense f32/f16 payloads on every link (the pre-sparsify wire).
    #[default]
    None,
    /// Ship the top `ratio` fraction of each network segment by
    /// magnitude (at least one entry), folding the dropped residual
    /// into the next step via per-rank error feedback.  `ratio = 1.0`
    /// sends every coordinate — exact, and bitwise-equal to the dense
    /// path whenever the gradient sums are exactly representable.
    TopK(f64),
}

impl Sparsify {
    /// Parse the `none | topk:RATIO` config/CLI spelling.
    pub fn parse(s: &str) -> std::result::Result<Sparsify, String> {
        let t = s.trim().to_ascii_lowercase();
        if t == "none" {
            return Ok(Sparsify::None);
        }
        if let Some(r) = t.strip_prefix("topk:") {
            let ratio: f64 = r.parse().map_err(|_| {
                format!("'{s}': topk ratio '{r}' is not a number")
            })?;
            if !(ratio > 0.0 && ratio <= 1.0) {
                return Err(format!(
                    "'{s}': topk ratio must be in (0, 1], got {ratio}"
                ));
            }
            return Ok(Sparsify::TopK(ratio));
        }
        Err(format!("'{s}': expected none | topk:RATIO"))
    }

    /// Top-k entry count for a segment of `len` elements: `ceil(ratio *
    /// len)`, floored at one entry so every rank always sends SOMETHING
    /// (the growth floor netsim prices) — except for empty segments.
    pub fn entries(self, len: usize) -> usize {
        match self {
            Sparsify::None => len,
            Sparsify::TopK(ratio) => {
                if len == 0 {
                    0
                } else {
                    ((ratio * len as f64).ceil() as usize).clamp(1, len)
                }
            }
        }
    }
}

impl std::fmt::Display for Sparsify {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Sparsify::None => f.write_str("none"),
            Sparsify::TopK(r) => write!(f, "topk:{r}"),
        }
    }
}

/// A sparsified gradient message: (index, value) pairs.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseGrad {
    pub n: usize,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseGrad {
    /// Wire size in bytes (4B index + 4B value per entry).
    pub fn wire_bytes(&self) -> usize {
        self.indices.len() * 8
    }

    /// Compression ratio vs the dense f32 payload.
    pub fn compression(&self) -> f64 {
        (self.n * 4) as f64 / self.wire_bytes().max(1) as f64
    }

    /// Densify back to a full vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n];
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] = v;
        }
        out
    }
}

/// Select the top-`k` entries by magnitude.  Exact selection via
/// partial sort of a sampled threshold would be cheaper; we use full
/// `select_nth_unstable` which is O(n) — the cost the paper counts as
/// "extra amount of calculation overhead".
pub fn top_k(grads: &[f32], k: usize) -> SparseGrad {
    let n = grads.len();
    let k = k.min(n);
    if k == 0 {
        return SparseGrad { n, indices: vec![], values: vec![] };
    }
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.select_nth_unstable_by(k - 1, |&a, &b| {
        grads[b as usize]
            .abs()
            .partial_cmp(&grads[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut indices: Vec<u32> = order[..k].to_vec();
    indices.sort_unstable();
    let values = indices.iter().map(|&i| grads[i as usize]).collect();
    SparseGrad { n, indices, values }
}

/// In-place [`top_k`] for the comm hot path: selection order scratch
/// and the output index/value buffers are caller-owned (recycled
/// through the transport's `PayloadPool`), so the steady-state step
/// performs no per-selection allocation.  `indices` comes out sorted
/// ascending with `values` parallel to it — identical content to
/// [`top_k`], asserted by a property test.
pub fn top_k_into(grads: &[f32], k: usize, order: &mut Vec<u32>,
                  indices: &mut Vec<u32>, values: &mut Vec<f32>) {
    indices.clear();
    values.clear();
    let n = grads.len();
    let k = k.min(n);
    if k == 0 {
        return;
    }
    order.clear();
    order.extend(0..n as u32);
    order.select_nth_unstable_by(k - 1, |&a, &b| {
        grads[b as usize]
            .abs()
            .partial_cmp(&grads[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    indices.extend_from_slice(&order[..k]);
    indices.sort_unstable();
    values.extend(indices.iter().map(|&i| grads[i as usize]));
}

/// Threshold-based sparsification (the tuning-sensitive alternative).
pub fn by_threshold(grads: &[f32], threshold: f32) -> SparseGrad {
    let mut indices = Vec::new();
    let mut values = Vec::new();
    for (i, &g) in grads.iter().enumerate() {
        if g.abs() >= threshold {
            indices.push(i as u32);
            values.push(g);
        }
    }
    SparseGrad { n: grads.len(), indices, values }
}

/// Sparsifying worker state with error feedback: dropped gradient mass
/// is carried into the next round instead of lost.
#[derive(Debug)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(n: usize) -> Self {
        Self { residual: vec![0.0; n] }
    }

    /// Sparsify `grads + residual`, keeping the dropped part as the new
    /// residual.  Returns the message to transmit.
    pub fn step(&mut self, grads: &[f32], k: usize) -> SparseGrad {
        assert_eq!(grads.len(), self.residual.len());
        let corrected: Vec<f32> = grads
            .iter()
            .zip(&self.residual)
            .map(|(g, r)| g + r)
            .collect();
        let msg = top_k(&corrected, k);
        // residual = corrected - sent
        self.residual = corrected;
        for (&i, &v) in msg.indices.iter().zip(&msg.values) {
            self.residual[i as usize] -= v;
        }
        msg
    }

    pub fn residual_norm(&self) -> f32 {
        crate::optimizer::l2_norm(&self.residual)
    }
}

/// Cosine similarity between the sparsified gradient and the dense one
/// (1.0 = undistorted signal) — the quality metric in the bench.
pub fn cosine_to_dense(msg: &SparseGrad, dense: &[f32]) -> f64 {
    let sparse = msg.to_dense();
    let dot: f64 = sparse.iter().zip(dense)
        .map(|(a, b)| *a as f64 * *b as f64).sum();
    let na: f64 = sparse.iter().map(|a| (*a as f64).powi(2)).sum::<f64>()
        .sqrt();
    let nb: f64 = dense.iter().map(|b| (*b as f64).powi(2)).sum::<f64>()
        .sqrt();
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / na / nb
    }
}

/// Synthetic "sparse-friendly" gradients (heavy-tailed) vs BERT-like
/// dense gradients — used by tests to show when sparsification works.
pub fn synth_heavy_tailed(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let u = rng.next_f64();
            // pareto-ish: most values tiny, a few huge
            let mag = (1.0 / (1.0 - u)).powf(1.5) * 1e-4;
            (mag * if rng.chance(0.5) { -1.0 } else { 1.0 }) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn top_k_picks_largest_magnitudes() {
        let g = vec![0.1, -5.0, 0.01, 3.0, -0.2];
        let s = top_k(&g, 2);
        assert_eq!(s.indices, vec![1, 3]);
        assert_eq!(s.values, vec![-5.0, 3.0]);
        assert_eq!(s.to_dense(), vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn top_k_edge_cases() {
        let g = vec![1.0, 2.0];
        assert_eq!(top_k(&g, 0).indices.len(), 0);
        assert_eq!(top_k(&g, 5).indices.len(), 2);
        assert_eq!(top_k(&[], 3).indices.len(), 0);
    }

    #[test]
    fn threshold_variant() {
        let g = vec![0.1, -5.0, 0.01, 3.0];
        let s = by_threshold(&g, 1.0);
        assert_eq!(s.indices, vec![1, 3]);
        // too-high threshold sends nothing (the paper's tuning risk)
        assert_eq!(by_threshold(&g, 10.0).indices.len(), 0);
    }

    #[test]
    fn compression_accounting() {
        let g = vec![1.0f32; 1000];
        let s = top_k(&g, 100);
        assert_eq!(s.wire_bytes(), 800);
        assert!((s.compression() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn error_feedback_conserves_gradient_mass() {
        // Over many rounds, sum(transmitted) ~= sum(all gradients).
        let n = 256;
        let mut ef = ErrorFeedback::new(n);
        let mut sent_total = vec![0.0f32; n];
        let mut grad_total = vec![0.0f32; n];
        let mut rng = Pcg64::new(5);
        for _ in 0..50 {
            let g: Vec<f32> =
                (0..n).map(|_| (rng.next_gaussian() * 0.1) as f32).collect();
            for (t, x) in grad_total.iter_mut().zip(&g) {
                *t += x;
            }
            let msg = ef.step(&g, 32);
            for (&i, &v) in msg.indices.iter().zip(&msg.values) {
                sent_total[i as usize] += v;
            }
        }
        // residual bounds the difference
        for i in 0..n {
            let diff = (grad_total[i] - sent_total[i]).abs();
            assert!(diff <= ef.residual_norm() + 1e-4, "i={i} diff={diff}");
        }
    }

    #[test]
    fn heavy_tailed_sparsifies_well_dense_does_not() {
        // The paper's §4.4 argument in one test: a heavy-tailed gradient
        // keeps high cosine similarity at 10:1 compression; a dense
        // gaussian gradient (BERT-like) does not.
        let n = 10_000;
        let heavy = synth_heavy_tailed(n, 7);
        let mut rng = Pcg64::new(8);
        let dense: Vec<f32> =
            (0..n).map(|_| (rng.next_gaussian() * 0.01) as f32).collect();
        let k = n / 10;
        let cos_heavy = cosine_to_dense(&top_k(&heavy, k), &heavy);
        let cos_dense = cosine_to_dense(&top_k(&dense, k), &dense);
        assert!(cos_heavy > 0.98, "{cos_heavy}");
        assert!(cos_dense < 0.85, "{cos_dense}");
        assert!(cos_heavy > cos_dense + 0.1);
    }

    #[test]
    fn sparsify_knob_parses_and_displays() {
        assert_eq!(Sparsify::parse("none").unwrap(), Sparsify::None);
        assert_eq!(Sparsify::parse(" NONE ").unwrap(), Sparsify::None);
        assert_eq!(Sparsify::parse("topk:0.01").unwrap(),
                   Sparsify::TopK(0.01));
        assert_eq!(Sparsify::parse("topk:1.0").unwrap(),
                   Sparsify::TopK(1.0));
        for bad in ["topk:0", "topk:1.5", "topk:-0.1", "topk:x", "dense"] {
            assert!(Sparsify::parse(bad).is_err(), "{bad} must not parse");
        }
        assert_eq!(Sparsify::TopK(0.25).to_string(), "topk:0.25");
        assert_eq!(Sparsify::None.to_string(), "none");
        let rt = Sparsify::parse(&Sparsify::TopK(0.01).to_string()).unwrap();
        assert_eq!(rt, Sparsify::TopK(0.01));
    }

    #[test]
    fn sparsify_entries_has_growth_floor() {
        let s = Sparsify::TopK(0.01);
        assert_eq!(s.entries(0), 0);
        assert_eq!(s.entries(1), 1);
        assert_eq!(s.entries(10), 1); // floor: ceil(0.1) = 1
        assert_eq!(s.entries(1000), 10);
        assert_eq!(Sparsify::TopK(1.0).entries(37), 37);
        assert_eq!(Sparsify::None.entries(37), 37);
    }

    #[test]
    fn prop_top_k_into_matches_top_k() {
        testkit::check(
            "topk-into", 0x59B, 48,
            |r| {
                let g = testkit::gen_f32_vec(r, 0, 300);
                let k = r.range_usize(0, g.len() + 2);
                (g, k)
            },
            |(g, k)| {
                let want = top_k(g, *k);
                let (mut order, mut idx, mut val) =
                    (Vec::new(), Vec::new(), Vec::new());
                top_k_into(g, *k, &mut order, &mut idx, &mut val);
                idx == want.indices && val == want.values
            },
        );
    }

    #[test]
    fn prop_topk_dense_roundtrip_subset() {
        testkit::check(
            "sparsify-subset", 0x59A, 48,
            |r| {
                let g = testkit::gen_f32_vec(r, 1, 300);
                let k = r.range_usize(0, g.len() + 1);
                (g, k)
            },
            |(g, k)| {
                let s = top_k(g, *k);
                // every transmitted value matches the original
                s.indices.iter().zip(&s.values).all(|(&i, &v)| {
                    g[i as usize] == v
                }) && s.indices.len() == (*k).min(g.len())
            },
        );
    }
}
