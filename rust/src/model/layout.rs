//! Flat parameter layout + the Figure-4 gradient memory profile.
//!
//! The paper's Figure 4 groups gradient memory by layer class to argue
//! that BERT's gradients are dense (dominated by attention /
//! intermediate / output matmul weights), making sparsification
//! unattractive.  [`GradientProfile`] reproduces that exact breakdown
//! from the layout.

use crate::jsonlite::Json;

/// One tensor in the flat parameter vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

impl LayoutEntry {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> usize {
        self.len() * 4
    }
}

/// The ordered flat layout (the manifest contract).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamLayout {
    entries: Vec<LayoutEntry>,
    total: usize,
}

impl ParamLayout {
    pub fn from_shapes(shapes: &[(String, Vec<usize>)]) -> ParamLayout {
        let mut entries = Vec::with_capacity(shapes.len());
        let mut off = 0usize;
        for (name, shape) in shapes {
            let e = LayoutEntry {
                name: name.clone(),
                offset: off,
                shape: shape.clone(),
            };
            off += e.len();
            entries.push(e);
        }
        ParamLayout { entries, total: off }
    }

    /// Parse from the manifest's `layout` array.
    pub fn from_manifest(layout: &Json) -> anyhow::Result<ParamLayout> {
        let arr = layout.as_arr()
            .ok_or_else(|| anyhow::anyhow!("layout is not an array"))?;
        let mut shapes = Vec::with_capacity(arr.len());
        for e in arr {
            let name = e.get("name").and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("layout entry missing name"))?;
            let shape: Vec<usize> = e
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("layout entry missing shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            shapes.push((name.to_string(), shape));
        }
        let built = ParamLayout::from_shapes(&shapes);
        // verify the manifest's offsets agree (defense against drift)
        for (e, m) in built.entries.iter().zip(arr) {
            let off = m.get("offset").and_then(Json::as_usize).unwrap_or(0);
            anyhow::ensure!(
                e.offset == off,
                "layout drift at {}: built offset {} != manifest {}",
                e.name, e.offset, off
            );
        }
        Ok(built)
    }

    pub fn entries(&self) -> &[LayoutEntry] {
        &self.entries
    }

    pub fn total_len(&self) -> usize {
        self.total
    }

    pub fn total_bytes(&self) -> usize {
        self.total * 4
    }

    pub fn find(&self, name: &str) -> Option<&LayoutEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// The Figure-4 gradient memory profile.
    pub fn gradient_profile(&self) -> GradientProfile {
        let mut p = GradientProfile::default();
        for e in &self.entries {
            let group = LayerGroup::classify(&e.name);
            p.add(group, e.bytes());
        }
        p
    }
}

/// Figure-4 layer classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerGroup {
    Embedding,
    Attention,
    Intermediate,
    Output,
    LayerNorm,
    Pooler,
    Classifier,
}

impl LayerGroup {
    pub const ALL: [LayerGroup; 7] = [
        LayerGroup::Embedding,
        LayerGroup::Attention,
        LayerGroup::Intermediate,
        LayerGroup::Output,
        LayerGroup::LayerNorm,
        LayerGroup::Pooler,
        LayerGroup::Classifier,
    ];

    pub fn name(self) -> &'static str {
        match self {
            LayerGroup::Embedding => "embedding",
            LayerGroup::Attention => "attention",
            LayerGroup::Intermediate => "intermediate",
            LayerGroup::Output => "output",
            LayerGroup::LayerNorm => "layernorm",
            LayerGroup::Pooler => "pooler",
            LayerGroup::Classifier => "classifier",
        }
    }

    /// Classify a parameter name into its Figure-4 group.
    pub fn classify(name: &str) -> LayerGroup {
        if name.contains("layernorm") {
            LayerGroup::LayerNorm
        } else if name.starts_with("embeddings.") {
            LayerGroup::Embedding
        } else if name.contains(".attention.") {
            LayerGroup::Attention
        } else if name.contains(".intermediate.") {
            LayerGroup::Intermediate
        } else if name.contains(".output.") {
            LayerGroup::Output
        } else if name.contains("pooler") {
            LayerGroup::Pooler
        } else {
            LayerGroup::Classifier
        }
    }
}

/// Bytes of gradient memory per layer group (Figure 4's bars).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GradientProfile {
    pub bytes: std::collections::BTreeMap<&'static str, usize>,
}

impl GradientProfile {
    fn add(&mut self, group: LayerGroup, bytes: usize) {
        *self.bytes.entry(group.name()).or_insert(0) += bytes;
    }

    pub fn total(&self) -> usize {
        self.bytes.values().sum()
    }

    /// Fraction of gradient bytes in the dense matmul groups — the
    /// paper's argument that sparsification won't help (§4.4).
    pub fn dense_fraction(&self) -> f64 {
        let dense: usize = ["attention", "intermediate", "output"]
            .iter()
            .filter_map(|g| self.bytes.get(g))
            .sum();
        dense as f64 / self.total().max(1) as f64
    }

    /// Rows for the Figure-4 bar chart, largest first.
    pub fn sorted_rows(&self) -> Vec<(String, f64)> {
        let mut rows: Vec<(String, f64)> = self
            .bytes
            .iter()
            .map(|(k, v)| (k.to_string(), *v as f64))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::BertConfig;

    #[test]
    fn classification_rules() {
        assert_eq!(LayerGroup::classify("embeddings.word_embeddings"),
                   LayerGroup::Embedding);
        assert_eq!(LayerGroup::classify("encoder.layer.3.attention.query.weight"),
                   LayerGroup::Attention);
        assert_eq!(LayerGroup::classify("encoder.layer.0.intermediate.weight"),
                   LayerGroup::Intermediate);
        assert_eq!(LayerGroup::classify("encoder.layer.0.output.weight"),
                   LayerGroup::Output);
        assert_eq!(LayerGroup::classify("encoder.layer.0.output.layernorm.gamma"),
                   LayerGroup::LayerNorm);
        assert_eq!(LayerGroup::classify("cls.pooler.weight"),
                   LayerGroup::Pooler);
        assert_eq!(LayerGroup::classify("cls.seq_relationship.weight"),
                   LayerGroup::Classifier);
    }

    #[test]
    fn bert_large_profile_matches_figure4_shape() {
        // Figure 4's claim: the majority of gradient bytes are in the
        // dense attention/intermediate/output matmuls.
        let cfg = BertConfig::preset("bert-large").unwrap();
        let profile = cfg.param_layout().gradient_profile();
        assert!(profile.dense_fraction() > 0.7,
                "dense fraction {}", profile.dense_fraction());
        // total = 340M params * 4B = 1.36 GB of gradients
        let gb = profile.total() as f64 / 1e9;
        assert!((gb - 1.345).abs() < 0.05, "{gb} GB");
        // attention is the largest single group for BERT-large
        let rows = profile.sorted_rows();
        assert_eq!(rows[0].0, "attention");
    }

    #[test]
    fn profile_total_matches_layout() {
        let cfg = BertConfig::preset("bert-mini").unwrap();
        let layout = cfg.param_layout();
        assert_eq!(layout.gradient_profile().total(), layout.total_bytes());
    }

    #[test]
    fn manifest_roundtrip() {
        let cfg = BertConfig::preset("bert-micro").unwrap();
        let layout = cfg.param_layout();
        // build a manifest-style JSON and parse it back
        let arr: Vec<Json> = layout
            .entries()
            .iter()
            .map(|e| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("name".to_string(), Json::Str(e.name.clone()));
                m.insert("offset".to_string(), Json::Num(e.offset as f64));
                m.insert(
                    "shape".to_string(),
                    Json::Arr(e.shape.iter().map(|&d| Json::Num(d as f64))
                        .collect()),
                );
                Json::Obj(m)
            })
            .collect();
        let parsed = ParamLayout::from_manifest(&Json::Arr(arr)).unwrap();
        assert_eq!(parsed, layout);
    }

    #[test]
    fn find_by_name() {
        let cfg = BertConfig::preset("bert-micro").unwrap();
        let layout = cfg.param_layout();
        let e = layout.find("embeddings.word_embeddings").unwrap();
        assert_eq!(e.offset, 0);
        assert_eq!(e.shape, vec![512, 64]);
        assert!(layout.find("nope").is_none());
    }
}
