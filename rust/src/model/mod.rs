//! Host-side model metadata: the flat parameter layout (the contract
//! with `python/compile/model.py::param_layout`), BERT config presets,
//! parameter counting, and the Figure-4 layer-group classification.

pub mod layout;

pub use layout::{GradientProfile, LayerGroup, ParamLayout};

/// BERT architecture hyper-parameters, mirroring the Python presets.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BertConfig {
    pub vocab_size: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub intermediate: usize,
    pub max_seq: usize,
    pub type_vocab: usize,
}

impl BertConfig {
    /// Named presets — MUST stay in sync with python/compile/model.py.
    pub fn preset(name: &str) -> Option<BertConfig> {
        let c = match name {
            "bert-micro" => BertConfig {
                vocab_size: 512, hidden: 64, layers: 2, heads: 2,
                intermediate: 256, max_seq: 64, type_vocab: 2,
            },
            "bert-tiny" => BertConfig {
                vocab_size: 8192, hidden: 128, layers: 2, heads: 2,
                intermediate: 512, max_seq: 512, type_vocab: 2,
            },
            "bert-mini" => BertConfig {
                vocab_size: 8192, hidden: 256, layers: 4, heads: 4,
                intermediate: 1024, max_seq: 512, type_vocab: 2,
            },
            "bert-medium" => BertConfig {
                vocab_size: 8192, hidden: 512, layers: 8, heads: 8,
                intermediate: 2048, max_seq: 512, type_vocab: 2,
            },
            "bert-base" => BertConfig {
                vocab_size: 30522, hidden: 768, layers: 12, heads: 12,
                intermediate: 3072, max_seq: 512, type_vocab: 2,
            },
            "bert-large" => BertConfig {
                vocab_size: 30522, hidden: 1024, layers: 24, heads: 16,
                intermediate: 4096, max_seq: 512, type_vocab: 2,
            },
            _ => return None,
        };
        Some(c)
    }

    /// Build the flat parameter layout — same order as the Python side.
    pub fn param_layout(&self) -> ParamLayout {
        let (h, i, v) = (self.hidden, self.intermediate, self.vocab_size);
        let mut shapes: Vec<(String, Vec<usize>)> = vec![
            ("embeddings.word_embeddings".into(), vec![v, h]),
            ("embeddings.position_embeddings".into(), vec![self.max_seq, h]),
            ("embeddings.token_type_embeddings".into(),
             vec![self.type_vocab, h]),
            ("embeddings.layernorm.gamma".into(), vec![h]),
            ("embeddings.layernorm.beta".into(), vec![h]),
        ];
        for l in 0..self.layers {
            let p = format!("encoder.layer.{l}");
            for (suffix, shape) in [
                ("attention.query.weight", vec![h, h]),
                ("attention.query.bias", vec![h]),
                ("attention.key.weight", vec![h, h]),
                ("attention.key.bias", vec![h]),
                ("attention.value.weight", vec![h, h]),
                ("attention.value.bias", vec![h]),
                ("attention.output.weight", vec![h, h]),
                ("attention.output.bias", vec![h]),
                ("attention.layernorm.gamma", vec![h]),
                ("attention.layernorm.beta", vec![h]),
                ("intermediate.weight", vec![h, i]),
                ("intermediate.bias", vec![i]),
                ("output.weight", vec![i, h]),
                ("output.bias", vec![h]),
                ("output.layernorm.gamma", vec![h]),
                ("output.layernorm.beta", vec![h]),
            ] {
                shapes.push((format!("{p}.{suffix}"), shape));
            }
        }
        shapes.extend([
            ("cls.predictions.transform.weight".to_string(), vec![h, h]),
            ("cls.predictions.transform.bias".to_string(), vec![h]),
            ("cls.predictions.layernorm.gamma".to_string(), vec![h]),
            ("cls.predictions.layernorm.beta".to_string(), vec![h]),
            ("cls.predictions.bias".to_string(), vec![v]),
            ("cls.pooler.weight".to_string(), vec![h, h]),
            ("cls.pooler.bias".to_string(), vec![h]),
            ("cls.seq_relationship.weight".to_string(), vec![h, 2]),
            ("cls.seq_relationship.bias".to_string(), vec![2]),
        ]);
        ParamLayout::from_shapes(&shapes)
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.param_layout().total_len()
    }

    /// FLOPs for one fwd+bwd pass per token (the standard 6*N
    /// approximation for transformer training).
    pub fn train_flops_per_token(&self) -> f64 {
        6.0 * self.param_count() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_counts_match_python_side() {
        // Values verified against python/compile/model.py param_count.
        assert_eq!(BertConfig::preset("bert-micro").unwrap().param_count(),
                   146_178);
        assert_eq!(BertConfig::preset("bert-base").unwrap().param_count(),
                   110_106_428);
        assert_eq!(BertConfig::preset("bert-large").unwrap().param_count(),
                   336_226_108);
    }

    #[test]
    fn published_model_sizes() {
        // paper §1: 110M (base), 340M (large)
        let base = BertConfig::preset("bert-base").unwrap().param_count();
        let large = BertConfig::preset("bert-large").unwrap().param_count();
        assert!((105_000_000..115_000_000).contains(&base));
        assert!((330_000_000..345_000_000).contains(&large));
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(BertConfig::preset("bert-gigantic").is_none());
    }

    #[test]
    fn layout_is_dense() {
        let cfg = BertConfig::preset("bert-tiny").unwrap();
        let layout = cfg.param_layout();
        let mut off = 0;
        for e in layout.entries() {
            assert_eq!(e.offset, off, "{}", e.name);
            off += e.len();
        }
        assert_eq!(off, cfg.param_count());
    }
}
