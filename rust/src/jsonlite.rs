//! Minimal JSON parser + writer (substrate: `serde_json` unavailable
//! offline).  Parses the AOT `manifest.json` contract and emits metric /
//! chrome-trace output.  Supports the full JSON grammar except `\u`
//! surrogate pairs beyond the BMP (not needed for the manifest).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(thiserror::Error, Debug)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors (ergonomic manifest navigation) --

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path accessor: `j.path(&["models", "bert-tiny", "param_count"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp)
                                .unwrap_or(char::REPLACEMENT_CHARACTER));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
                       Some(c) if c.is_ascii_digit() || c == b'.'
                           || c == b'e' || c == b'E' || c == b'+'
                           || c == b'-') {
            self.pos += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"version": 1, "models": {"m": {"param_count": 146178,
            "layout": [{"name": "w", "offset": 0, "shape": [2, 3]}],
            "ok": true, "x": null, "f": -1.5e2}}}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.path(&["models", "m", "param_count"]).unwrap()
                       .as_usize(), Some(146178));
        let layout = j.path(&["models", "m", "layout"]).unwrap()
            .as_arr().unwrap();
        assert_eq!(layout[0].get("name").unwrap().as_str(), Some("w"));
        assert_eq!(j.path(&["models", "m", "f"]).unwrap().as_f64(),
                   Some(-150.0));
    }

    #[test]
    fn string_escapes_round_trip() {
        let j = Json::Str("a\"b\\c\nd\te".to_string());
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip_nested() {
        let doc = r#"{"a":[1,2,{"b":[true,false,null]}],"c":"x"}"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
