//! Network/interconnect timing model (paper §3.2 / §4.4 substrate).
//!
//! The real testbed's fabric (10 Gb/s Ethernet between nodes, 64 Gb/s
//! PCIe within a node) is replaced by an analytic model: each transfer
//! costs `latency + bytes / bandwidth`, and each physical port (a node's
//! NIC, a GPU's PCIe lane) is a serializing [`Resource`] — concurrent
//! transfers through the same port queue up, which is exactly the
//! congestion the paper's §4.1/§4.4 scheduling avoids.
//!
//! The data path in the trainer is real memory; this module only supplies
//! *time*.  The discrete-event simulator composes these with compute
//! spans to regenerate Figures 2/3/5/6.

use crate::topology::{DeviceId, LinkKind, Topology};

/// An analytic point-to-point link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Usable bandwidth in bits per second.
    pub bandwidth_bps: f64,
    /// One-way message latency in seconds.
    pub latency_s: f64,
}

impl LinkModel {
    /// Time to move `bytes` over this link.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        self.latency_s + bytes * 8.0 / self.bandwidth_bps
    }

    /// Effective bandwidth achieved for a transfer of `bytes` (B/s).
    pub fn effective_bandwidth(&self, bytes: f64) -> f64 {
        bytes / self.transfer_time(bytes)
    }
}

/// The cluster's fabric: link models per [`LinkKind`].
#[derive(Debug, Clone, Copy)]
pub struct Fabric {
    pub pcie: LinkModel,
    pub network: LinkModel,
}

impl Fabric {
    /// The paper's Table-1 fabric: 64 Gb/s PCIe, 10 Gb/s Ethernet.
    pub fn paper() -> Self {
        Self {
            pcie: LinkModel { bandwidth_bps: 64e9, latency_s: 5e-6 },
            network: LinkModel { bandwidth_bps: 10e9, latency_s: 50e-6 },
        }
    }

    /// Link model between two devices in `topo`.
    pub fn link(&self, topo: &Topology, a: DeviceId, b: DeviceId)
        -> Option<LinkModel> {
        match topo.link(a, b) {
            LinkKind::Local => None,
            LinkKind::Pcie => Some(self.pcie),
            LinkKind::Network => Some(self.network),
        }
    }

    /// The bottleneck link model of a ring over `topo` (the slowest hop
    /// paces every ring step — the paper's 10 Gb/s network).
    pub fn ring_bottleneck(&self, topo: &Topology) -> LinkModel {
        if topo.machines > 1 {
            self.network
        } else {
            self.pcie
        }
    }
}

/// A serializing physical resource (NIC, PCIe switch port, GPU compute
/// stream).  Reservations model queueing: a request issued at `t` starts
/// at `max(t, next_free)`.
#[derive(Debug, Clone, Default)]
pub struct Resource {
    next_free: f64,
    busy_total: f64,
}

impl Resource {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve the resource for `duration` starting no earlier than
    /// `ready`; returns (start, end).
    pub fn reserve(&mut self, ready: f64, duration: f64) -> (f64, f64) {
        let start = ready.max(self.next_free);
        let end = start + duration;
        self.next_free = end;
        self.busy_total += duration;
        (start, end)
    }

    /// Earliest time a new reservation could start.
    pub fn next_free(&self) -> f64 {
        self.next_free
    }

    /// Total busy time accumulated (for utilization reports).
    pub fn busy_total(&self) -> f64 {
        self.busy_total
    }

    /// Utilization in [0,1] over a horizon.
    pub fn utilization(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            (self.busy_total / horizon).min(1.0)
        }
    }
}

/// Analytic ring-allreduce time over `n` participants for a payload of
/// `bytes`, paced by `link` (paper §2.2: 2(n-1)/n of the data crosses
/// each link; each of the 2(n-1) steps pays one message latency).
pub fn ring_allreduce_time(n: usize, bytes: f64, link: LinkModel) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    let chunk = bytes / n as f64;
    steps as f64 * (link.latency_s + chunk * 8.0 / link.bandwidth_bps)
}

/// Analytic hierarchical allreduce (paper §4.4 resource separation):
/// reduce within each node over PCIe, ring over node leaders on the
/// network, then broadcast within nodes over PCIe.
pub fn hierarchical_allreduce_time(topo: &Topology, bytes: f64,
                                   fabric: &Fabric) -> f64 {
    let g = topo.gpus_per_machine;
    let m = topo.machines;
    let intra = ring_allreduce_time(g, bytes, fabric.pcie);
    let inter = ring_allreduce_time(m, bytes, fabric.network);
    // reduce-scatter+gather within node ~= one ring allreduce; the final
    // intra-node broadcast is bytes*(g-1)/g per link, approximate as half
    // a ring pass.
    let bcast = if g > 1 { 0.5 * intra } else { 0.0 };
    intra + inter + bcast
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    #[test]
    fn transfer_time_components() {
        let l = LinkModel { bandwidth_bps: 10e9, latency_s: 50e-6 };
        // 1.25 GB over 10 Gb/s = 1 s (+latency)
        let t = l.transfer_time(1.25e9);
        assert!((t - 1.00005).abs() < 1e-6, "{t}");
    }

    #[test]
    fn paper_fabric_hierarchy() {
        let f = Fabric::paper();
        assert!(f.pcie.bandwidth_bps > f.network.bandwidth_bps);
        let topo = Topology::new(2, 4);
        assert_eq!(f.ring_bottleneck(&topo), f.network);
        let single = Topology::new(1, 8);
        assert_eq!(f.ring_bottleneck(&single), f.pcie);
    }

    #[test]
    fn resource_serializes_overlapping_requests() {
        let mut r = Resource::new();
        let (s1, e1) = r.reserve(0.0, 1.0);
        let (s2, e2) = r.reserve(0.5, 1.0); // wants to start mid-flight
        assert_eq!((s1, e1), (0.0, 1.0));
        assert_eq!((s2, e2), (1.0, 2.0)); // queued behind the first
        let (s3, _) = r.reserve(5.0, 1.0); // idle gap respected
        assert_eq!(s3, 5.0);
        assert_eq!(r.busy_total(), 3.0);
    }

    #[test]
    fn ring_allreduce_formula() {
        // n=2: 2 steps of half the payload each => ~ payload/bw total.
        let link = LinkModel { bandwidth_bps: 10e9, latency_s: 0.0 };
        let t = ring_allreduce_time(2, 1.36e9, link);
        assert!((t - 1.36e9 * 8.0 / 10e9).abs() < 1e-9, "{t}");
        // n=1 is free
        assert_eq!(ring_allreduce_time(1, 1e9, link), 0.0);
    }

    #[test]
    fn ring_time_approaches_2x_bandwidth_bound() {
        // As n grows, total time -> 2 * bytes / bw (the classic bound).
        let link = LinkModel { bandwidth_bps: 10e9, latency_s: 0.0 };
        let bytes = 1e9;
        let t256 = ring_allreduce_time(256, bytes, link);
        let bound = 2.0 * bytes * 8.0 / 10e9;
        assert!((t256 - bound * 255.0 / 256.0).abs() < 1e-9);
        assert!(t256 < bound);
    }

    #[test]
    fn hierarchical_vs_flat_ring_regimes() {
        // Bandwidth-dominated regime (paper fabric, huge payload): both
        // schemes move ~2*M over the per-node NIC, so they are within
        // ~25% of each other; hierarchical pays the intra-node passes.
        let topo = Topology::new(32, 8);
        let f = Fabric::paper();
        let bytes = 1.36e9; // BERT-large f32 grads
        let flat = ring_allreduce_time(topo.world_size(), bytes, f.network);
        let hier = hierarchical_allreduce_time(&topo, bytes, &f);
        assert!((hier - flat).abs() / flat < 0.25, "hier={hier} flat={flat}");

        // Latency-dominated regime: the flat ring pays 2*(256-1) network
        // latencies, the hierarchical one only 2*(32-1) — with a 5 ms
        // per-message latency hierarchical must win clearly.
        let slow = Fabric {
            pcie: f.pcie,
            network: LinkModel { bandwidth_bps: 10e9, latency_s: 5e-3 },
        };
        let flat_l = ring_allreduce_time(topo.world_size(), bytes, slow.network);
        let hier_l = hierarchical_allreduce_time(&topo, bytes, &slow);
        assert!(hier_l < flat_l, "hier={hier_l} flat={flat_l}");
    }

    #[test]
    fn prop_ring_time_monotone_in_payload() {
        testkit::check(
            "ring-monotone", 0xA2, 64,
            |r: &mut Pcg64| (r.range_usize(2, 300),
                             r.next_f64() * 1e9 + 1.0),
            |&(n, bytes)| {
                let link = LinkModel { bandwidth_bps: 10e9, latency_s: 1e-5 };
                ring_allreduce_time(n, bytes, link)
                    < ring_allreduce_time(n, bytes * 2.0, link)
            },
        );
    }

    #[test]
    fn utilization_bounded() {
        let mut r = Resource::new();
        r.reserve(0.0, 2.0);
        assert!((r.utilization(4.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.utilization(0.0), 0.0);
        assert_eq!(r.utilization(1.0), 1.0); // clamped
    }
}
