//! Network/interconnect timing model (paper §3.2 / §4.4 substrate).
//!
//! The real testbed's fabric (10 Gb/s Ethernet between nodes, 64 Gb/s
//! PCIe within a node) is replaced by an analytic model: each transfer
//! costs `latency + bytes / bandwidth`, and each physical port (a node's
//! NIC, a GPU's PCIe lane) is a serializing [`Resource`] — concurrent
//! transfers through the same port queue up, which is exactly the
//! congestion the paper's §4.1/§4.4 scheduling avoids.
//!
//! The data path in the trainer is real memory; this module only supplies
//! *time*.  The discrete-event simulator composes these with compute
//! spans to regenerate Figures 2/3/5/6.
//!
//! ## Invariants
//!
//! * Every model here prices the schedule the pool actually EXECUTES —
//!   [`hierarchical_allreduce_phases`] the serialized-leader transfers,
//!   [`hierarchical_pipelined_phases`] the chunked chain pipeline,
//!   [`hierarchical_rs_phases`] the 2-level reduce-scatter shards; when
//!   the executed schedule changes, the model changes with it (the
//!   fig6/table4 benches assert the correspondence).
//! * Transfer times are strictly positive and monotone in payload;
//!   [`Resource`] utilization is clamped to `[0, 1]`.
//! * [`hierarchical_pipelined_phases`] degrades exactly to the
//!   serialized pricing at one chunk (`chunk_bytes >= bytes`), so the
//!   two models can never disagree on the unpipelined schedule.

use crate::grad::sparsify::Sparsify;
use crate::topology::{DeviceId, LinkKind, Topology};

/// An analytic point-to-point link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Usable bandwidth in bits per second.
    pub bandwidth_bps: f64,
    /// One-way message latency in seconds.
    pub latency_s: f64,
}

impl LinkModel {
    /// Time to move `bytes` over this link.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        self.latency_s + bytes * 8.0 / self.bandwidth_bps
    }

    /// Effective bandwidth achieved for a transfer of `bytes` (B/s).
    pub fn effective_bandwidth(&self, bytes: f64) -> f64 {
        bytes / self.transfer_time(bytes)
    }
}

/// The cluster's fabric: link models per [`LinkKind`].
#[derive(Debug, Clone, Copy)]
pub struct Fabric {
    pub pcie: LinkModel,
    pub network: LinkModel,
}

impl Fabric {
    /// The paper's Table-1 fabric: 64 Gb/s PCIe, 10 Gb/s Ethernet.
    pub fn paper() -> Self {
        Self {
            pcie: LinkModel { bandwidth_bps: 64e9, latency_s: 5e-6 },
            network: LinkModel { bandwidth_bps: 10e9, latency_s: 50e-6 },
        }
    }

    /// Link model between two devices in `topo`.
    pub fn link(&self, topo: &Topology, a: DeviceId, b: DeviceId)
        -> Option<LinkModel> {
        match topo.link(a, b) {
            LinkKind::Local => None,
            LinkKind::Pcie => Some(self.pcie),
            LinkKind::Network => Some(self.network),
        }
    }

    /// The bottleneck link model of a ring over `topo` (the slowest hop
    /// paces every ring step — the paper's 10 Gb/s network).
    pub fn ring_bottleneck(&self, topo: &Topology) -> LinkModel {
        if topo.machines > 1 {
            self.network
        } else {
            self.pcie
        }
    }
}

/// A serializing physical resource (NIC, PCIe switch port, GPU compute
/// stream).  Reservations model queueing: a request issued at `t` starts
/// at `max(t, next_free)`.
#[derive(Debug, Clone, Default)]
pub struct Resource {
    next_free: f64,
    busy_total: f64,
}

impl Resource {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve the resource for `duration` starting no earlier than
    /// `ready`; returns (start, end).
    pub fn reserve(&mut self, ready: f64, duration: f64) -> (f64, f64) {
        let start = ready.max(self.next_free);
        let end = start + duration;
        self.next_free = end;
        self.busy_total += duration;
        (start, end)
    }

    /// Earliest time a new reservation could start.
    pub fn next_free(&self) -> f64 {
        self.next_free
    }

    /// Total busy time accumulated (for utilization reports).
    pub fn busy_total(&self) -> f64 {
        self.busy_total
    }

    /// Utilization in [0,1] over a horizon.
    pub fn utilization(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            (self.busy_total / horizon).min(1.0)
        }
    }
}

/// Analytic ring-allreduce time over `n` participants for a payload of
/// `bytes`, paced by `link` (paper §2.2: 2(n-1)/n of the data crosses
/// each link; each of the 2(n-1) steps pays one message latency).
pub fn ring_allreduce_time(n: usize, bytes: f64, link: LinkModel) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    let chunk = bytes / n as f64;
    steps as f64 * (link.latency_s + chunk * 8.0 / link.bandwidth_bps)
}

/// PCIe/network split of the analytic hierarchical allreduce time.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct HierPhases {
    /// Intra-node seconds: leader accumulate + broadcast over PCIe.
    pub pcie_s: f64,
    /// Inter-node seconds: the leader ring over the network.
    pub net_s: f64,
}

impl HierPhases {
    pub fn total(&self) -> f64 {
        self.pcie_s + self.net_s
    }
}

/// Analytic hierarchical allreduce phases (paper §4.4 resource
/// separation), priced to match the schedule
/// `collectives::hierarchical_allreduce_inplace` and the pooled
/// hierarchical exchange actually EXECUTE:
///
/// 1. leader accumulate — `(g-1)` serialized full-payload transfers into
///    the node leader over PCIe (not a ring: the leader's PCIe port is
///    the serializing resource);
/// 2. leader ring allreduce over the `m` node leaders on the network
///    (the standard `2(m-1)` step ring);
/// 3. leader broadcast — `(g-1)` serialized full-payload copies back out
///    of the leader over PCIe.
///
/// An earlier model priced phase 1+3 as intra-node *ring* passes, which
/// undercounted the executed serialized transfers ~3x at g=8 — the
/// Figure-6 regeneration must price what actually runs.
pub fn hierarchical_allreduce_phases(topo: &Topology, bytes: f64,
                                     fabric: &Fabric) -> HierPhases {
    let g = topo.gpus_per_machine;
    let m = topo.machines;
    let serial_pcie = (g.saturating_sub(1)) as f64
        * fabric.pcie.transfer_time(bytes);
    HierPhases {
        // accumulate in + broadcast out: both serialized at the leader
        pcie_s: 2.0 * serial_pcie,
        net_s: ring_allreduce_time(m, bytes, fabric.network),
    }
}

/// Total analytic hierarchical allreduce time (sum of
/// [`hierarchical_allreduce_phases`]).
pub fn hierarchical_allreduce_time(topo: &Topology, bytes: f64,
                                   fabric: &Fabric) -> f64 {
    hierarchical_allreduce_phases(topo, bytes, fabric).total()
}

/// Price the bandwidth-optimal 2-level reduce-scatter schedule
/// (`train.intra_node = rs`, executed by the pool's `rs_comm_loop`):
///
/// 1. intra-node reduce-scatter — `(g-1)` ring steps, each moving one
///    `bytes/g` chunk per PCIe link (every member transmits
///    concurrently, unlike the serialized leader funnel);
/// 2. cross-machine shard rings — every rank runs an `m`-machine ring
///    allreduce over ONLY its owned `bytes/g` shard; the `g` rings run
///    concurrently over distinct same-local-index links, so the priced
///    per-link payload is `bytes/g`, not `bytes`;
/// 3. intra-node allgather — `(g-1)` more `bytes/g` ring steps.
///
/// Per-link traffic is therefore `O(n/g)` on BOTH fabrics — the NCCL
/// 2-level form — versus the serialized leader's `O(n)` full-payload
/// hops ([`hierarchical_allreduce_phases`]).  Degenerates exactly to
/// the leader pricing at `g = 1` (no intra phases; the shard IS the
/// bucket).
pub fn hierarchical_rs_phases(topo: &Topology, bytes: f64,
                              fabric: &Fabric) -> HierPhases {
    let g = topo.gpus_per_machine;
    let m = topo.machines;
    let shard = bytes / g.max(1) as f64;
    let pcie_s = if g > 1 {
        2.0 * (g - 1) as f64 * fabric.pcie.transfer_time(shard)
    } else {
        0.0
    };
    HierPhases {
        pcie_s,
        net_s: ring_allreduce_time(m, shard, fabric.network),
    }
}

/// Pricing of the chunked pipelined intra-node schedule
/// (`train.intra_node = ring`, executed by the pool's chain workers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelinedHier {
    /// Chunks the payload splits into (`ceil(bytes / chunk_bytes)`).
    pub chunks: usize,
    /// One chunk's time through one PCIe chain link.
    pub chunk_pcie_s: f64,
    /// One chunk's leader-ring time on the network.
    pub chunk_net_s: f64,
    /// Critical-path wall of the pipelined gather → ring → broadcast.
    pub wall_s: f64,
    /// NIC busy seconds (`chunks * chunk_net_s`) — the network phase.
    pub net_busy_s: f64,
}

impl PipelinedHier {
    /// Exposed PCIe seconds: the wall not covered by network busy time
    /// (chain fill/drain plus any steady-state PCIe-bound overhang).
    pub fn pcie_exposed_s(&self) -> f64 {
        (self.wall_s - self.net_busy_s).max(0.0)
    }
}

/// Price the chunked pipelined hierarchical allreduce (the
/// `IntraNodeMode::Ring` schedule `collectives::pool` executes): the
/// payload splits into `ceil(bytes / chunk_bytes)` chunks that flow
/// through the `(g-1)`-link member chain toward the leader, ring over
/// the `m` leaders per chunk, and flow back.  The critical path is the
/// classic pipeline formula — fill and drain the chain once
/// (`2(g-1)` chunk link times) plus one chunk through the ring, with
/// the remaining `C-1` chunks paced by the slower of the two stages:
///
/// ```text
/// wall = 2(g-1)·t(s) + r(s) + (C-1)·max(t(s), r(s))
/// ```
///
/// where `t(s)` is one chunk's PCIe link time and `r(s)` its m-leader
/// ring time.  Degenerates exactly to the serialized-leader pricing
/// ([`hierarchical_allreduce_phases`]) when `chunk_bytes >= bytes`
/// (one chunk: no pipelining to exploit), and exposes the latency
/// blow-up of over-chunking at large `m` — `C` rings pay `C` times the
/// `2(m-1)` message latencies — so the knob has a real optimum the
/// benches sweep.
pub fn hierarchical_pipelined_phases(topo: &Topology, bytes: f64,
                                     fabric: &Fabric, chunk_bytes: f64)
                                     -> PipelinedHier {
    let g = topo.gpus_per_machine;
    let m = topo.machines;
    let chunk_bytes = chunk_bytes.max(1.0).min(bytes.max(1.0));
    let chunks = (bytes / chunk_bytes).ceil().max(1.0);
    let s = bytes / chunks;
    let t = if g > 1 { fabric.pcie.transfer_time(s) } else { 0.0 };
    let r = ring_allreduce_time(m, s, fabric.network);
    let fill = 2.0 * g.saturating_sub(1) as f64 * t;
    let wall = fill + r + (chunks - 1.0) * t.max(r);
    PipelinedHier {
        chunks: chunks as usize,
        chunk_pcie_s: t,
        chunk_net_s: r,
        wall_s: wall,
        net_busy_s: chunks * r,
    }
}

/// Per-message wire overhead of one sparse frame, matching the
/// `collectives::transport` v1 codec exactly: a 4-byte length prefix
/// plus the 13-byte `kind | tag | n | count` body header.
pub const SPARSE_FRAME_OVERHEAD_BYTES: f64 = 17.0;

/// Bytes per transmitted sparse entry: u32 index + f32 value — the 2x
/// index overhead that makes `topk:1.0` cost MORE wire than dense f32.
pub const SPARSE_ENTRY_BYTES: f64 = 8.0;

/// One ratio point of the sparse-ring model (the grist the
/// `perf_hotpath` sparsify section sweeps into `BENCH_sparsify.json`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparseRingPoint {
    /// The `train.sparsify = topk:RATIO` knob value priced.
    pub ratio: f64,
    /// Entries each rank transmits per hop (the executed selector's
    /// `ceil(ratio*elems)` with its k >= 1 growth floor).
    pub entries: usize,
    /// Pure wire seconds of the sparse allgather ring.
    pub wire_s: f64,
    /// EF staleness inflation: modeled steps-to-target multiplier.
    pub inflation: f64,
    /// `wire_s * inflation` — seconds of network time per unit of
    /// training progress, the quantity with an interior optimum.
    pub effective_s: f64,
}

/// Time for the sparse exchange the pool actually executes on a
/// network ring under `train.sparsify = topk`: an
/// **allgather-of-messages** — top-k does not commute with
/// reduce-scatter chunking, so each of the `m-1` hops forwards one
/// origin's whole `(index, value)` message and every rank rebuilds the
/// sum locally in fixed origin order.  Per-link bytes are therefore
/// `(m-1) * (k*8 + frame overhead)` — versus the dense ring's
/// `2(m-1)/m * bytes` — which is why `topk:1.0` costs ~`m/2 * 2 = m`
/// times the dense wire: every coordinate ships `m-1` times with an
/// index bolted on, instead of `2(m-1)/m` times bare.
pub fn sparse_allgather_time(m: usize, elems: usize, ratio: f64,
                             link: LinkModel) -> f64 {
    if m <= 1 {
        return 0.0;
    }
    let k = Sparsify::TopK(ratio).entries(elems);
    let msg = k as f64 * SPARSE_ENTRY_BYTES + SPARSE_FRAME_OVERHEAD_BYTES;
    (m - 1) as f64 * link.transfer_time(msg)
}

/// Modeled convergence inflation of error-feedback top-k: at ratio `r`
/// a `(1-r)` fraction of each step's gradient mass arrives late through
/// the residual, so reaching a fixed target takes more steps.  The
/// standard EF analyses bound the extra term by `O((1-r)/r)`, which is
/// what this multiplier uses (`kappa` scales it; 1 at `r = 1`,
/// diverging as `r -> 0` — no free lunch at the aggressive end):
///
/// ```text
/// inflation(r) = 1 + kappa * (1 - r) / r
/// ```
pub fn ef_inflation(ratio: f64, kappa: f64) -> f64 {
    let r = ratio.clamp(1e-9, 1.0);
    1.0 + kappa * (1.0 - r) / r
}

/// Price one `train.sparsify = topk:RATIO` point on an `m`-machine
/// network ring of `elems` f32 gradients: wire time of the executed
/// sparse allgather, EF inflation, and their product.  The product has
/// an INTERIOR optimum in `r` — wire time grows affinely in `r` while
/// inflation decays like `1/r`, so the best ratio sits at
/// `r* ~ sqrt(overhead * kappa / slope)`, moved by exactly the two
/// costs the wire charges: per-hop latency+header overhead (pushing
/// `r*` up) and the 8 B/entry payload slope (pushing it down).
pub fn sparse_ring_cost(m: usize, elems: usize, ratio: f64,
                        link: LinkModel, kappa: f64) -> SparseRingPoint {
    let wire_s = sparse_allgather_time(m, elems, ratio, link);
    let inflation = ef_inflation(ratio, kappa);
    SparseRingPoint {
        ratio,
        entries: Sparsify::TopK(ratio).entries(elems),
        wire_s,
        inflation,
        effective_s: wire_s * inflation,
    }
}

/// Sweep [`sparse_ring_cost`] over a ratio grid and return every point
/// plus the argmin of `effective_s` (ties to the smaller ratio).
pub fn sparse_ratio_sweep(m: usize, elems: usize, link: LinkModel,
                          kappa: f64, grid: &[f64])
                          -> (Vec<SparseRingPoint>, SparseRingPoint) {
    assert!(!grid.is_empty(), "sparse ratio sweep needs a grid");
    let pts: Vec<SparseRingPoint> = grid
        .iter()
        .map(|&r| sparse_ring_cost(m, elems, r, link, kappa))
        .collect();
    let best = *pts
        .iter()
        .reduce(|a, b| if b.effective_s < a.effective_s { b } else { a })
        .unwrap();
    (pts, best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    #[test]
    fn transfer_time_components() {
        let l = LinkModel { bandwidth_bps: 10e9, latency_s: 50e-6 };
        // 1.25 GB over 10 Gb/s = 1 s (+latency)
        let t = l.transfer_time(1.25e9);
        assert!((t - 1.00005).abs() < 1e-6, "{t}");
    }

    #[test]
    fn paper_fabric_hierarchy() {
        let f = Fabric::paper();
        assert!(f.pcie.bandwidth_bps > f.network.bandwidth_bps);
        let topo = Topology::new(2, 4);
        assert_eq!(f.ring_bottleneck(&topo), f.network);
        let single = Topology::new(1, 8);
        assert_eq!(f.ring_bottleneck(&single), f.pcie);
    }

    #[test]
    fn resource_serializes_overlapping_requests() {
        let mut r = Resource::new();
        let (s1, e1) = r.reserve(0.0, 1.0);
        let (s2, e2) = r.reserve(0.5, 1.0); // wants to start mid-flight
        assert_eq!((s1, e1), (0.0, 1.0));
        assert_eq!((s2, e2), (1.0, 2.0)); // queued behind the first
        let (s3, _) = r.reserve(5.0, 1.0); // idle gap respected
        assert_eq!(s3, 5.0);
        assert_eq!(r.busy_total(), 3.0);
    }

    #[test]
    fn ring_allreduce_formula() {
        // n=2: 2 steps of half the payload each => ~ payload/bw total.
        let link = LinkModel { bandwidth_bps: 10e9, latency_s: 0.0 };
        let t = ring_allreduce_time(2, 1.36e9, link);
        assert!((t - 1.36e9 * 8.0 / 10e9).abs() < 1e-9, "{t}");
        // n=1 is free
        assert_eq!(ring_allreduce_time(1, 1e9, link), 0.0);
    }

    #[test]
    fn ring_time_approaches_2x_bandwidth_bound() {
        // As n grows, total time -> 2 * bytes / bw (the classic bound).
        let link = LinkModel { bandwidth_bps: 10e9, latency_s: 0.0 };
        let bytes = 1e9;
        let t256 = ring_allreduce_time(256, bytes, link);
        let bound = 2.0 * bytes * 8.0 / 10e9;
        assert!((t256 - bound * 255.0 / 256.0).abs() < 1e-9);
        assert!(t256 < bound);
    }

    #[test]
    fn hierarchical_phases_price_the_executed_schedule() {
        // The model must match what `hierarchical_allreduce_inplace` and
        // the pooled hierarchical exchange actually do: (g-1) serialized
        // leader-accumulate PCIe transfers, an m-leader network ring,
        // (g-1) serialized broadcast PCIe transfers.
        let topo = Topology::new(4, 3);
        let f = Fabric::paper();
        let bytes = 2.0e8;
        let p = hierarchical_allreduce_phases(&topo, bytes, &f);
        let want_pcie = 2.0 * 2.0 * f.pcie.transfer_time(bytes);
        let want_net = ring_allreduce_time(4, bytes, f.network);
        assert!((p.pcie_s - want_pcie).abs() < 1e-12, "{p:?}");
        assert!((p.net_s - want_net).abs() < 1e-12, "{p:?}");
        assert!((p.total() - hierarchical_allreduce_time(&topo, bytes, &f))
                    .abs() < 1e-12);
    }

    #[test]
    fn hierarchical_degenerates_to_leader_ring_at_g1() {
        // One GPU per machine: no PCIe phases; the "hierarchy" IS the
        // flat ring over the machines.
        let topo = Topology::new(8, 1);
        let f = Fabric::paper();
        let bytes = 1e8;
        let p = hierarchical_allreduce_phases(&topo, bytes, &f);
        assert_eq!(p.pcie_s, 0.0);
        assert!((p.total()
                 - ring_allreduce_time(8, bytes, f.network)).abs() < 1e-12);
    }

    #[test]
    fn hierarchical_vs_flat_ring_regimes() {
        let f = Fabric::paper();

        // The §4.4 win the hierarchy always delivers: the network phase
        // rings over m leaders instead of m*g ranks, so the time spent
        // on the slow fabric strictly drops (fewer latency terms AND a
        // smaller 2(n-1)/n factor).
        let topo = Topology::new(32, 8);
        let bytes = 1.36e9; // BERT-large f32 grads
        let flat = ring_allreduce_time(topo.world_size(), bytes, f.network);
        let hier_net =
            hierarchical_allreduce_phases(&topo, bytes, &f).net_s;
        assert!(hier_net < flat, "net {hier_net} vs flat {flat}");

        // Small node fan-in (g=2): the two serialized PCIe hops are
        // cheap, so the hierarchy wins outright — the flat ring drags
        // the payload through 2*(64-1) network-paced steps.
        let small = Topology::new(32, 2);
        let b2 = 1e6;
        let flat2 = ring_allreduce_time(small.world_size(), b2, f.network);
        let hier2 = hierarchical_allreduce_time(&small, b2, &f);
        assert!(hier2 < flat2, "hier={hier2} flat={flat2}");

        // Wide nodes (g=8), bandwidth-dominated: the executed schedule's
        // (g-1) serialized full-payload PCIe transfers are its honest
        // cost — the model must NOT hide them, so total time exceeds the
        // flat ring here even though the NIC carries less.
        let hier8 = hierarchical_allreduce_time(&topo, bytes, &f);
        assert!(hier8 > flat, "hier={hier8} flat={flat}");
    }

    #[test]
    fn rs_phases_price_the_shard_schedule() {
        // Both fabrics move bytes/g per link: 2(g-1) intra ring steps of
        // one shard each, and an m-ring over one shard on the network.
        let topo = Topology::new(4, 3);
        let f = Fabric::paper();
        let bytes = 2.0e8;
        let p = hierarchical_rs_phases(&topo, bytes, &f);
        let shard = bytes / 3.0;
        let want_pcie = 2.0 * 2.0 * f.pcie.transfer_time(shard);
        let want_net = ring_allreduce_time(4, shard, f.network);
        assert!((p.pcie_s - want_pcie).abs() < 1e-12, "{p:?}");
        assert!((p.net_s - want_net).abs() < 1e-12, "{p:?}");
    }

    #[test]
    fn rs_beats_serialized_leader_whenever_nodes_are_wide() {
        // O(n/g) per link: every g > 1 topology prices strictly below
        // the serialized-leader schedule on BOTH phases — including the
        // perf_hotpath 2M4G anchor.
        let f = Fabric::paper();
        for (m, g) in [(2, 4), (4, 3), (32, 8), (2, 2)] {
            let topo = Topology::new(m, g);
            for bytes in [1e6, 2e8, 1.36e9] {
                let rs = hierarchical_rs_phases(&topo, bytes, &f);
                let leader = hierarchical_allreduce_phases(&topo, bytes, &f);
                assert!(rs.pcie_s < leader.pcie_s,
                        "{m}M{g}G {bytes}: {rs:?} vs {leader:?}");
                assert!(rs.net_s < leader.net_s,
                        "{m}M{g}G {bytes}: {rs:?} vs {leader:?}");
            }
        }
        // Bandwidth-dominated regime: the shard ring carries 1/g of the
        // leader ring's per-link bytes, so net time shrinks ~g-fold.
        let topo = Topology::new(4, 8);
        let rs = hierarchical_rs_phases(&topo, 1.36e9, &f);
        let leader = hierarchical_allreduce_phases(&topo, 1.36e9, &f);
        assert!(rs.net_s < leader.net_s / 6.0,
                "rs {} vs leader {}", rs.net_s, leader.net_s);
    }

    #[test]
    fn rs_degenerates_to_leader_ring_at_g1() {
        // One GPU per machine: the shard IS the bucket, no intra phases
        // — identical pricing to the serialized-leader degenerate form.
        let topo = Topology::new(8, 1);
        let f = Fabric::paper();
        let bytes = 1e8;
        let p = hierarchical_rs_phases(&topo, bytes, &f);
        let leader = hierarchical_allreduce_phases(&topo, bytes, &f);
        assert_eq!(p.pcie_s, 0.0);
        assert!((p.total() - leader.total()).abs() < 1e-12);
    }

    #[test]
    fn pipelined_degenerates_to_serial_at_one_chunk() {
        // chunk >= payload: no pipelining to exploit, so the pipelined
        // model must price EXACTLY what the serialized-leader model
        // prices (fill = 2(g-1) full-payload link times + one ring).
        let topo = Topology::new(4, 3);
        let f = Fabric::paper();
        let bytes = 2.0e8;
        let serial = hierarchical_allreduce_phases(&topo, bytes, &f);
        for chunk in [bytes, bytes * 10.0] {
            let p = hierarchical_pipelined_phases(&topo, bytes, &f, chunk);
            assert_eq!(p.chunks, 1);
            assert!((p.wall_s - serial.total()).abs() < 1e-12, "{p:?}");
            assert!((p.net_busy_s - serial.net_s).abs() < 1e-12);
        }
    }

    #[test]
    fn pipelined_beats_serial_on_wide_nodes() {
        // g=8, bandwidth-dominated: the serialized leader pays 14
        // full-payload PCIe transfers; the pipeline amortizes the chain
        // fill over many chunks, so total wall drops well below it.
        let topo = Topology::new(32, 8);
        let f = Fabric::paper();
        let bytes = 1.36e9; // BERT-large f32 grads
        let serial = hierarchical_allreduce_time(&topo, bytes, &f);
        let p = hierarchical_pipelined_phases(&topo, bytes, &f,
                                              4.0 * (1 << 20) as f64);
        assert!(p.chunks > 100, "{p:?}");
        assert!(p.wall_s < serial,
                "pipelined {} vs serial {serial}", p.wall_s);
        assert!(p.net_busy_s <= p.wall_s + 1e-12);
        assert!(p.pcie_exposed_s() >= 0.0);
    }

    #[test]
    fn over_chunking_pays_ring_latency() {
        // The model must expose the tradeoff the knob controls: at
        // m=32, every chunk's leader ring pays 2(m-1) message
        // latencies, so tiny chunks are latency-dominated and WORSE
        // than moderate ones (and than the serial schedule).
        let topo = Topology::new(32, 8);
        let f = Fabric::paper();
        let bytes = 1.36e9;
        let tiny =
            hierarchical_pipelined_phases(&topo, bytes, &f, 64.0 * 1024.0);
        let moderate = hierarchical_pipelined_phases(&topo, bytes, &f,
                                                     4.0 * (1 << 20) as f64);
        assert!(tiny.wall_s > moderate.wall_s,
                "tiny {} vs moderate {}", tiny.wall_s, moderate.wall_s);
        assert!(tiny.wall_s
                    > hierarchical_allreduce_time(&topo, bytes, &f));
    }

    #[test]
    fn prop_ring_time_monotone_in_payload() {
        testkit::check(
            "ring-monotone", 0xA2, 64,
            |r: &mut Pcg64| (r.range_usize(2, 300),
                             r.next_f64() * 1e9 + 1.0),
            |&(n, bytes)| {
                let link = LinkModel { bandwidth_bps: 10e9, latency_s: 1e-5 };
                ring_allreduce_time(n, bytes, link)
                    < ring_allreduce_time(n, bytes * 2.0, link)
            },
        );
    }

    #[test]
    fn sparse_full_ratio_costs_more_wire_than_dense() {
        // topk:1.0 ships every coordinate m-1 times WITH an 8B entry
        // (vs 2(m-1)/m dense f32 passes) — the model must price that
        // honestly wherever bytes dominate.  (Tiny latency-bound
        // payloads are the one exception: the allgather's m-1 hops pay
        // HALF the dense ring's 2(m-1) message latencies.)
        let f = Fabric::paper();
        for m in [2usize, 4, 32] {
            for elems in [1usize << 18, 1 << 22] {
                let bytes = (elems * 4) as f64;
                let dense = ring_allreduce_time(m, bytes, f.network);
                let sparse = sparse_allgather_time(m, elems, 1.0, f.network);
                assert!(sparse > dense,
                        "m={m} elems={elems}: sparse {sparse} <= {dense}");
            }
        }
        // bandwidth-dominated regime: the blow-up approaches m x
        let sparse = sparse_allgather_time(8, 1 << 24, 1.0, f.network);
        let dense = ring_allreduce_time(8, (1u64 << 26) as f64, f.network);
        assert!(sparse / dense > 6.0, "{}", sparse / dense);
    }

    #[test]
    fn sparse_wire_time_monotone_in_ratio_with_growth_floor() {
        let f = Fabric::paper();
        let elems = 1 << 18;
        let mut prev = 0.0;
        for r in [0.001, 0.01, 0.1, 0.5, 1.0] {
            let t = sparse_allgather_time(4, elems, r, f.network);
            assert!(t >= prev, "ratio {r}: {t} < {prev}");
            prev = t;
        }
        // the k >= 1 growth floor: even an absurd ratio on a tiny
        // segment still prices one full entry per hop, never zero
        let tiny = sparse_ring_cost(4, 3, 1e-6, f.network, 0.1);
        assert_eq!(tiny.entries, 1);
        assert!(tiny.wire_s > 0.0);
        // and a single machine has no network ring to sparsify
        assert_eq!(sparse_allgather_time(1, elems, 0.1, f.network), 0.0);
    }

    #[test]
    fn sparse_effective_cost_has_an_interior_ratio_optimum() {
        // Wire time grows ~affinely in the ratio while EF inflation
        // decays like 1/r: the effective cost must bottom out strictly
        // inside (grid[0], 1.0) for BERT-scale payloads — neither "send
        // almost nothing" nor "send everything" wins.
        let f = Fabric::paper();
        let grid: Vec<f64> =
            (0..60).map(|i| 10f64.powf(-4.0 + i as f64 * 4.0 / 59.0))
                   .collect();
        let elems = 336_226_108 / 26; // one of ~26 BERT-large buckets
        let (pts, best) =
            sparse_ratio_sweep(4, elems, f.network, 0.05, &grid);
        assert_eq!(pts.len(), grid.len());
        assert!(best.ratio > grid[0] && best.ratio < 1.0,
                "optimum {best:?} sits on the grid edge");
        // the endpoints really are worse
        assert!(pts[0].effective_s > best.effective_s * 1.05,
                "aggressive end not penalized: {:?}", pts[0]);
        assert!(pts[pts.len() - 1].effective_s > best.effective_s * 1.05,
                "dense end not penalized: {:?}", pts[pts.len() - 1]);
        // inflation is 1 exactly at the dense end, > 1 below it
        assert!((ef_inflation(1.0, 0.05) - 1.0).abs() < 1e-12);
        assert!(ef_inflation(0.01, 0.05) > 1.0);
    }

    #[test]
    fn sparse_model_uses_the_executed_selectors_k() {
        // Model/executor agreement: the priced entry count IS
        // Sparsify::entries — if the selector's rounding changes, this
        // pins the model to change with it.
        for (elems, ratio) in [(1000usize, 0.01), (10, 0.01), (7, 1.0)] {
            let p = sparse_ring_cost(2, elems, ratio,
                                     Fabric::paper().network, 0.0);
            assert_eq!(p.entries,
                       crate::grad::sparsify::Sparsify::TopK(ratio)
                           .entries(elems));
        }
    }

    #[test]
    fn utilization_bounded() {
        let mut r = Resource::new();
        r.reserve(0.0, 2.0);
        assert!((r.utilization(4.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.utilization(0.0), 0.0);
        assert_eq!(r.utilization(1.0), 1.0); // clamped
    }
}
