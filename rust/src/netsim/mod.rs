//! Network/interconnect timing model (paper §3.2 / §4.4 substrate).
//!
//! The real testbed's fabric (10 Gb/s Ethernet between nodes, 64 Gb/s
//! PCIe within a node) is replaced by an analytic model: each transfer
//! costs `latency + bytes / bandwidth`, and each physical port (a node's
//! NIC, a GPU's PCIe lane) is a serializing [`Resource`] — concurrent
//! transfers through the same port queue up, which is exactly the
//! congestion the paper's §4.1/§4.4 scheduling avoids.
//!
//! The data path in the trainer is real memory; this module only supplies
//! *time*.  The discrete-event simulator composes these with compute
//! spans to regenerate Figures 2/3/5/6.

use crate::topology::{DeviceId, LinkKind, Topology};

/// An analytic point-to-point link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Usable bandwidth in bits per second.
    pub bandwidth_bps: f64,
    /// One-way message latency in seconds.
    pub latency_s: f64,
}

impl LinkModel {
    /// Time to move `bytes` over this link.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        self.latency_s + bytes * 8.0 / self.bandwidth_bps
    }

    /// Effective bandwidth achieved for a transfer of `bytes` (B/s).
    pub fn effective_bandwidth(&self, bytes: f64) -> f64 {
        bytes / self.transfer_time(bytes)
    }
}

/// The cluster's fabric: link models per [`LinkKind`].
#[derive(Debug, Clone, Copy)]
pub struct Fabric {
    pub pcie: LinkModel,
    pub network: LinkModel,
}

impl Fabric {
    /// The paper's Table-1 fabric: 64 Gb/s PCIe, 10 Gb/s Ethernet.
    pub fn paper() -> Self {
        Self {
            pcie: LinkModel { bandwidth_bps: 64e9, latency_s: 5e-6 },
            network: LinkModel { bandwidth_bps: 10e9, latency_s: 50e-6 },
        }
    }

    /// Link model between two devices in `topo`.
    pub fn link(&self, topo: &Topology, a: DeviceId, b: DeviceId)
        -> Option<LinkModel> {
        match topo.link(a, b) {
            LinkKind::Local => None,
            LinkKind::Pcie => Some(self.pcie),
            LinkKind::Network => Some(self.network),
        }
    }

    /// The bottleneck link model of a ring over `topo` (the slowest hop
    /// paces every ring step — the paper's 10 Gb/s network).
    pub fn ring_bottleneck(&self, topo: &Topology) -> LinkModel {
        if topo.machines > 1 {
            self.network
        } else {
            self.pcie
        }
    }
}

/// A serializing physical resource (NIC, PCIe switch port, GPU compute
/// stream).  Reservations model queueing: a request issued at `t` starts
/// at `max(t, next_free)`.
#[derive(Debug, Clone, Default)]
pub struct Resource {
    next_free: f64,
    busy_total: f64,
}

impl Resource {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve the resource for `duration` starting no earlier than
    /// `ready`; returns (start, end).
    pub fn reserve(&mut self, ready: f64, duration: f64) -> (f64, f64) {
        let start = ready.max(self.next_free);
        let end = start + duration;
        self.next_free = end;
        self.busy_total += duration;
        (start, end)
    }

    /// Earliest time a new reservation could start.
    pub fn next_free(&self) -> f64 {
        self.next_free
    }

    /// Total busy time accumulated (for utilization reports).
    pub fn busy_total(&self) -> f64 {
        self.busy_total
    }

    /// Utilization in [0,1] over a horizon.
    pub fn utilization(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            (self.busy_total / horizon).min(1.0)
        }
    }
}

/// Analytic ring-allreduce time over `n` participants for a payload of
/// `bytes`, paced by `link` (paper §2.2: 2(n-1)/n of the data crosses
/// each link; each of the 2(n-1) steps pays one message latency).
pub fn ring_allreduce_time(n: usize, bytes: f64, link: LinkModel) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    let chunk = bytes / n as f64;
    steps as f64 * (link.latency_s + chunk * 8.0 / link.bandwidth_bps)
}

/// PCIe/network split of the analytic hierarchical allreduce time.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct HierPhases {
    /// Intra-node seconds: leader accumulate + broadcast over PCIe.
    pub pcie_s: f64,
    /// Inter-node seconds: the leader ring over the network.
    pub net_s: f64,
}

impl HierPhases {
    pub fn total(&self) -> f64 {
        self.pcie_s + self.net_s
    }
}

/// Analytic hierarchical allreduce phases (paper §4.4 resource
/// separation), priced to match the schedule
/// `collectives::hierarchical_allreduce_inplace` and the pooled
/// hierarchical exchange actually EXECUTE:
///
/// 1. leader accumulate — `(g-1)` serialized full-payload transfers into
///    the node leader over PCIe (not a ring: the leader's PCIe port is
///    the serializing resource);
/// 2. leader ring allreduce over the `m` node leaders on the network
///    (the standard `2(m-1)` step ring);
/// 3. leader broadcast — `(g-1)` serialized full-payload copies back out
///    of the leader over PCIe.
///
/// An earlier model priced phase 1+3 as intra-node *ring* passes, which
/// undercounted the executed serialized transfers ~3x at g=8 — the
/// Figure-6 regeneration must price what actually runs.
pub fn hierarchical_allreduce_phases(topo: &Topology, bytes: f64,
                                     fabric: &Fabric) -> HierPhases {
    let g = topo.gpus_per_machine;
    let m = topo.machines;
    let serial_pcie = (g.saturating_sub(1)) as f64
        * fabric.pcie.transfer_time(bytes);
    HierPhases {
        // accumulate in + broadcast out: both serialized at the leader
        pcie_s: 2.0 * serial_pcie,
        net_s: ring_allreduce_time(m, bytes, fabric.network),
    }
}

/// Total analytic hierarchical allreduce time (sum of
/// [`hierarchical_allreduce_phases`]).
pub fn hierarchical_allreduce_time(topo: &Topology, bytes: f64,
                                   fabric: &Fabric) -> f64 {
    hierarchical_allreduce_phases(topo, bytes, fabric).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    #[test]
    fn transfer_time_components() {
        let l = LinkModel { bandwidth_bps: 10e9, latency_s: 50e-6 };
        // 1.25 GB over 10 Gb/s = 1 s (+latency)
        let t = l.transfer_time(1.25e9);
        assert!((t - 1.00005).abs() < 1e-6, "{t}");
    }

    #[test]
    fn paper_fabric_hierarchy() {
        let f = Fabric::paper();
        assert!(f.pcie.bandwidth_bps > f.network.bandwidth_bps);
        let topo = Topology::new(2, 4);
        assert_eq!(f.ring_bottleneck(&topo), f.network);
        let single = Topology::new(1, 8);
        assert_eq!(f.ring_bottleneck(&single), f.pcie);
    }

    #[test]
    fn resource_serializes_overlapping_requests() {
        let mut r = Resource::new();
        let (s1, e1) = r.reserve(0.0, 1.0);
        let (s2, e2) = r.reserve(0.5, 1.0); // wants to start mid-flight
        assert_eq!((s1, e1), (0.0, 1.0));
        assert_eq!((s2, e2), (1.0, 2.0)); // queued behind the first
        let (s3, _) = r.reserve(5.0, 1.0); // idle gap respected
        assert_eq!(s3, 5.0);
        assert_eq!(r.busy_total(), 3.0);
    }

    #[test]
    fn ring_allreduce_formula() {
        // n=2: 2 steps of half the payload each => ~ payload/bw total.
        let link = LinkModel { bandwidth_bps: 10e9, latency_s: 0.0 };
        let t = ring_allreduce_time(2, 1.36e9, link);
        assert!((t - 1.36e9 * 8.0 / 10e9).abs() < 1e-9, "{t}");
        // n=1 is free
        assert_eq!(ring_allreduce_time(1, 1e9, link), 0.0);
    }

    #[test]
    fn ring_time_approaches_2x_bandwidth_bound() {
        // As n grows, total time -> 2 * bytes / bw (the classic bound).
        let link = LinkModel { bandwidth_bps: 10e9, latency_s: 0.0 };
        let bytes = 1e9;
        let t256 = ring_allreduce_time(256, bytes, link);
        let bound = 2.0 * bytes * 8.0 / 10e9;
        assert!((t256 - bound * 255.0 / 256.0).abs() < 1e-9);
        assert!(t256 < bound);
    }

    #[test]
    fn hierarchical_phases_price_the_executed_schedule() {
        // The model must match what `hierarchical_allreduce_inplace` and
        // the pooled hierarchical exchange actually do: (g-1) serialized
        // leader-accumulate PCIe transfers, an m-leader network ring,
        // (g-1) serialized broadcast PCIe transfers.
        let topo = Topology::new(4, 3);
        let f = Fabric::paper();
        let bytes = 2.0e8;
        let p = hierarchical_allreduce_phases(&topo, bytes, &f);
        let want_pcie = 2.0 * 2.0 * f.pcie.transfer_time(bytes);
        let want_net = ring_allreduce_time(4, bytes, f.network);
        assert!((p.pcie_s - want_pcie).abs() < 1e-12, "{p:?}");
        assert!((p.net_s - want_net).abs() < 1e-12, "{p:?}");
        assert!((p.total() - hierarchical_allreduce_time(&topo, bytes, &f))
                    .abs() < 1e-12);
    }

    #[test]
    fn hierarchical_degenerates_to_leader_ring_at_g1() {
        // One GPU per machine: no PCIe phases; the "hierarchy" IS the
        // flat ring over the machines.
        let topo = Topology::new(8, 1);
        let f = Fabric::paper();
        let bytes = 1e8;
        let p = hierarchical_allreduce_phases(&topo, bytes, &f);
        assert_eq!(p.pcie_s, 0.0);
        assert!((p.total()
                 - ring_allreduce_time(8, bytes, f.network)).abs() < 1e-12);
    }

    #[test]
    fn hierarchical_vs_flat_ring_regimes() {
        let f = Fabric::paper();

        // The §4.4 win the hierarchy always delivers: the network phase
        // rings over m leaders instead of m*g ranks, so the time spent
        // on the slow fabric strictly drops (fewer latency terms AND a
        // smaller 2(n-1)/n factor).
        let topo = Topology::new(32, 8);
        let bytes = 1.36e9; // BERT-large f32 grads
        let flat = ring_allreduce_time(topo.world_size(), bytes, f.network);
        let hier_net =
            hierarchical_allreduce_phases(&topo, bytes, &f).net_s;
        assert!(hier_net < flat, "net {hier_net} vs flat {flat}");

        // Small node fan-in (g=2): the two serialized PCIe hops are
        // cheap, so the hierarchy wins outright — the flat ring drags
        // the payload through 2*(64-1) network-paced steps.
        let small = Topology::new(32, 2);
        let b2 = 1e6;
        let flat2 = ring_allreduce_time(small.world_size(), b2, f.network);
        let hier2 = hierarchical_allreduce_time(&small, b2, &f);
        assert!(hier2 < flat2, "hier={hier2} flat={flat2}");

        // Wide nodes (g=8), bandwidth-dominated: the executed schedule's
        // (g-1) serialized full-payload PCIe transfers are its honest
        // cost — the model must NOT hide them, so total time exceeds the
        // flat ring here even though the NIC carries less.
        let hier8 = hierarchical_allreduce_time(&topo, bytes, &f);
        assert!(hier8 > flat, "hier={hier8} flat={flat}");
    }

    #[test]
    fn prop_ring_time_monotone_in_payload() {
        testkit::check(
            "ring-monotone", 0xA2, 64,
            |r: &mut Pcg64| (r.range_usize(2, 300),
                             r.next_f64() * 1e9 + 1.0),
            |&(n, bytes)| {
                let link = LinkModel { bandwidth_bps: 10e9, latency_s: 1e-5 };
                ring_allreduce_time(n, bytes, link)
                    < ring_allreduce_time(n, bytes * 2.0, link)
            },
        );
    }

    #[test]
    fn utilization_bounded() {
        let mut r = Resource::new();
        r.reserve(0.0, 2.0);
        assert!((r.utilization(4.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.utilization(0.0), 0.0);
        assert_eq!(r.utilization(1.0), 1.0); // clamped
    }
}
