//! AOT manifest parsing — the contract emitted by `python/compile/aot.py`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::jsonlite::Json;
use crate::model::layout::ParamLayout;
use crate::model::BertConfig;

/// One lowered artifact (an .hlo.txt file).
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    /// Manifest key, e.g. "train_fused_f32_b8_s128".
    pub key: String,
    /// File name within the artifacts dir.
    pub file: String,
    /// Input (shape, dtype) list in call order.
    pub inputs: Vec<(Vec<usize>, String)>,
    /// Output names in tuple order.
    pub outputs: Vec<String>,
}

/// One model preset's artifact set.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub preset: String,
    pub config: BertConfig,
    pub param_count: usize,
    /// Pretraining params + QA span head (paper §5.3 fine-tuning).
    pub finetune_param_count: usize,
    pub layout: ParamLayout,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl ModelInfo {
    /// Find a train-step artifact for (variant, batch, seq).
    pub fn train_key(&self, variant: &str, batch: usize, seq: usize)
        -> Option<&ArtifactInfo> {
        self.artifacts.get(&format!("train_{variant}_b{batch}_s{seq}"))
    }

    /// All train-step artifacts, as (variant, batch, seq, info).
    pub fn train_artifacts(&self) -> Vec<(String, usize, usize, &ArtifactInfo)> {
        self.artifacts
            .iter()
            .filter(|(k, _)| k.starts_with("train_"))
            .filter_map(|(k, a)| {
                let rest = &k["train_".len()..];
                let bpos = rest.rfind("_b")?;
                let spos = rest.rfind("_s")?;
                let variant = rest[..bpos].to_string();
                let batch: usize = rest[bpos + 2..spos].parse().ok()?;
                let seq: usize = rest[spos + 2..].parse().ok()?;
                Some((variant, batch, seq, a))
            })
            .collect()
    }
}

/// The full manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {path:?}: {e}. Run `make artifacts` first."
            )
        })?;
        let json = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let mut models = BTreeMap::new();
        let model_objs = json
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("manifest missing models"))?;
        for (name, m) in model_objs {
            models.insert(name.clone(), parse_model(name, m)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, preset: &str) -> anyhow::Result<&ModelInfo> {
        self.models.get(preset).ok_or_else(|| {
            anyhow::anyhow!(
                "preset '{preset}' not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path of an artifact file.
    pub fn artifact_path(&self, art: &ArtifactInfo) -> PathBuf {
        self.dir.join(&art.file)
    }
}

fn parse_model(name: &str, m: &Json) -> anyhow::Result<ModelInfo> {
    let cfg_json = m.get("config")
        .ok_or_else(|| anyhow::anyhow!("model {name}: missing config"))?;
    let get = |k: &str| -> anyhow::Result<usize> {
        cfg_json.get(k).and_then(Json::as_usize).ok_or_else(|| {
            anyhow::anyhow!("model {name}: config missing {k}")
        })
    };
    let config = BertConfig {
        vocab_size: get("vocab_size")?,
        hidden: get("hidden")?,
        layers: get("layers")?,
        heads: get("heads")?,
        intermediate: get("intermediate")?,
        max_seq: get("max_seq")?,
        type_vocab: get("type_vocab")?,
    };
    let param_count = m.get("param_count").and_then(Json::as_usize)
        .ok_or_else(|| anyhow::anyhow!("model {name}: missing param_count"))?;
    let finetune_param_count = m.get("finetune_param_count")
        .and_then(Json::as_usize)
        .unwrap_or(param_count + config.hidden * 2 + 2);
    let layout = ParamLayout::from_manifest(
        m.get("layout")
            .ok_or_else(|| anyhow::anyhow!("model {name}: missing layout"))?,
    )?;
    anyhow::ensure!(
        layout.total_len() == param_count,
        "model {name}: layout total {} != param_count {param_count}",
        layout.total_len()
    );
    // cross-check against the Rust-side preset definition
    if let Some(rust_cfg) = BertConfig::preset(name) {
        anyhow::ensure!(
            rust_cfg == config,
            "model {name}: python/rust preset drift: {config:?} vs {rust_cfg:?}"
        );
    }

    let mut artifacts = BTreeMap::new();
    let arts = m.get("artifacts").and_then(Json::as_obj)
        .ok_or_else(|| anyhow::anyhow!("model {name}: missing artifacts"))?;
    for (key, a) in arts {
        let file = a.get("file").and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("artifact {key}: missing file"))?
            .to_string();
        let inputs = a
            .get("inputs")
            .and_then(Json::as_arr)
            .map(|arr| {
                arr.iter()
                    .map(|i| {
                        let shape: Vec<usize> = i
                            .get("shape")
                            .and_then(Json::as_arr)
                            .map(|s| s.iter().filter_map(Json::as_usize)
                                .collect())
                            .unwrap_or_default();
                        let dtype = i
                            .get("dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("float32")
                            .to_string();
                        (shape, dtype)
                    })
                    .collect()
            })
            .unwrap_or_default();
        let outputs = a
            .get("outputs")
            .and_then(Json::as_arr)
            .map(|arr| {
                arr.iter().filter_map(|o| o.as_str().map(String::from))
                    .collect()
            })
            .unwrap_or_default();
        artifacts.insert(
            key.clone(),
            ArtifactInfo { key: key.clone(), file, inputs, outputs },
        );
    }
    Ok(ModelInfo {
        preset: name.to_string(),
        config,
        param_count,
        finetune_param_count,
        layout,
        artifacts,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("bert-micro"));
        let micro = m.model("bert-micro").unwrap();
        assert_eq!(micro.param_count, 146_178);
        assert_eq!(micro.layout.total_len(), 146_178);
        assert!(micro.train_key("fused_f32", 2, 32).is_some());
        assert!(micro.artifacts.contains_key("apply_lamb"));
        let trains = micro.train_artifacts();
        assert!(trains.iter().any(|(v, b, s, _)|
            v == "fused_f32" && *b == 2 && *s == 32));
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz"))
            .unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
