//! PJRT runtime (the RT layer of DESIGN.md §4): loads the AOT manifest,
//! compiles HLO-text artifacts on the CPU PJRT client, and exposes typed
//! train/apply/forward steps over flat `f32` buffers.
//!
//! Python is NEVER invoked here — the artifacts in `artifacts/` are the
//! only hand-off (HLO text, not serialized protos; see aot_recipe).

pub mod engine;
pub mod manifest;

pub use engine::{ApplyStep, Engine, ForwardStep, QaBatch, QaOutput,
                 QaStats, QaStep, StepOutput, StepScratch, StepStats,
                 TrainStep};
pub use manifest::{ArtifactInfo, Manifest, ModelInfo};
