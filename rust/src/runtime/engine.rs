//! PJRT execution engine: compile HLO-text artifacts once, then execute
//! them from the training hot path with zero Python involvement.
//!
//! Adapted from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`.  The AOT
//! side lowers with `return_tuple=True`, so every result is a 1-tuple
//! whose element is the function's (possibly tuple) output.

use std::path::Path;

use anyhow::{Context, Result};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{ArtifactInfo, Manifest, ModelInfo};
use crate::data::Batch;

/// Output of one train-step execution.
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub loss: f32,
    pub mlm_loss: f32,
    pub nsp_loss: f32,
    pub mlm_acc: f32,
    pub grads: Vec<f32>,
    pub grad_norm: f32,
}

/// Scalar outputs of the zero-copy step path — everything in
/// [`StepOutput`] except the gradients, which land in the caller's
/// `grads_out` buffer instead of a fresh `Vec`.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStats {
    pub loss: f32,
    pub mlm_loss: f32,
    pub nsp_loss: f32,
    pub mlm_acc: f32,
    pub grad_norm: f32,
}

/// The engine: one PJRT client + the manifest it serves artifacts from.
pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn cpu(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("PjRtClient::cpu")?;
        Ok(Engine { client, manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_artifact(&self, art: &ArtifactInfo)
        -> Result<PjRtLoadedExecutable> {
        let path = self.manifest.artifact_path(art);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("XLA compile {}", art.key))
    }

    /// Compile the train step for (preset, variant, batch, seq).
    pub fn train_step(&self, preset: &str, variant: &str, batch: usize,
                      seq: usize) -> Result<TrainStep> {
        let model = self.manifest.model(preset)?;
        let art = model.train_key(variant, batch, seq).ok_or_else(|| {
            anyhow::anyhow!(
                "no train artifact for {preset}/{variant} b{batch} s{seq}; \
                 available: {:?}",
                model.artifacts.keys().collect::<Vec<_>>()
            )
        })?;
        Ok(TrainStep {
            exe: self.compile_artifact(art)?,
            n_params: model.param_count,
            batch,
            seq,
            key: art.key.clone(),
        })
    }

    /// Compile the optimizer apply step ("lamb" | "adam").
    pub fn apply_step(&self, preset: &str, optimizer: &str)
        -> Result<ApplyStep> {
        let model = self.manifest.model(preset)?;
        let key = format!("apply_{optimizer}");
        let art = model.artifacts.get(&key).ok_or_else(|| {
            anyhow::anyhow!("no artifact {key} for {preset}")
        })?;
        Ok(ApplyStep {
            exe: self.compile_artifact(art)?,
            n_params: model.param_count,
        })
    }

    /// Compile the QA fine-tuning step (paper §5.3).
    pub fn qa_step(&self, preset: &str, batch: usize, seq: usize)
        -> Result<QaStep> {
        let model = self.manifest.model(preset)?;
        let key = format!("qa_train_b{batch}_s{seq}");
        let art = model.artifacts.get(&key).ok_or_else(|| {
            anyhow::anyhow!("no artifact {key} for {preset}")
        })?;
        Ok(QaStep {
            exe: self.compile_artifact(art)?,
            n_params: model.finetune_param_count,
            batch,
            seq,
        })
    }

    /// Compile the QA optimizer apply (AdamW over the extended vector).
    pub fn qa_apply(&self, preset: &str) -> Result<ApplyStep> {
        let model = self.manifest.model(preset)?;
        let art = model.artifacts.get("qa_apply").ok_or_else(|| {
            anyhow::anyhow!("no artifact qa_apply for {preset}")
        })?;
        Ok(ApplyStep {
            exe: self.compile_artifact(art)?,
            n_params: model.finetune_param_count,
        })
    }

    /// Compile the eval-only forward step.
    pub fn forward_step(&self, preset: &str, variant: &str, batch: usize,
                        seq: usize) -> Result<ForwardStep> {
        let model = self.manifest.model(preset)?;
        let key = format!("fwd_{variant}_b{batch}_s{seq}");
        let art = model.artifacts.get(&key).ok_or_else(|| {
            anyhow::anyhow!("no artifact {key} for {preset}")
        })?;
        Ok(ForwardStep {
            exe: self.compile_artifact(art)?,
            n_params: model.param_count,
        })
    }

    pub fn model(&self, preset: &str) -> Result<&ModelInfo> {
        self.manifest.model(preset)
    }
}

// ---------------------------------------------------------- marshaling --

/// Reusable per-worker marshaling scratch for the zero-copy step paths
/// ([`TrainStep::run_scratch`] / [`QaStep::run_scratch`]).
///
/// The two gradient-sized host↔device marshals of the old path are
/// recycled here:
///
/// * the **params literal** (`n_params` f32s, rebuilt per micro-step
///   before) is cached and rebuilt only when the caller-supplied
///   `(buffer, version)` key changes — within one optimizer step every
///   micro-step shares the same parameters, so the rebuild happens once
///   per step instead of `accum_steps` times;
/// * the **loss-scale scalar** is cached by value (it only changes on
///   AMP back-off/growth).
///
/// The per-batch i32 tensors still get fresh (constant-shape, few-KB)
/// literals each call — they change every micro-step and carry no
/// gradient-sized payload.  The matching output-side recycling is
/// [`TrainStep::run_scratch`]'s `grads_out` parameter: gradients are
/// decoded straight into the caller's preallocated buffer instead of
/// materializing a fresh `Vec<f32>` of `n_params` per micro-step.
///
/// Contract: `params_version` MUST change whenever the parameter
/// contents change (the trainer passes its monotone data-step counter;
/// an in-place optimizer apply does not move the buffer, so pointer
/// identity alone cannot detect the update).
#[derive(Default)]
pub struct StepScratch {
    params_lit: Option<Literal>,
    params_key: Option<(usize, usize, u64)>,
    scale_lit: Option<Literal>,
    scale_val: f32,
}

// SAFETY: a `Literal` is host-side memory exclusively owned by this
// scratch — nothing in it is thread-affine.  The raw-pointer wrapper
// merely defeats the auto trait; the trainer parks each scratch behind a
// per-rank `Mutex`, so only one worker ever touches it at a time (the
// same reasoning as the `Send`/`Sync` impls on `TrainStep` below).
unsafe impl Send for StepScratch {}

impl StepScratch {
    pub fn new() -> StepScratch {
        StepScratch::default()
    }

    fn ensure_params(&mut self, params: &[f32], version: u64)
        -> Result<()> {
        let key = (params.as_ptr() as usize, params.len(), version);
        if self.params_key != Some(key) {
            self.params_lit = Some(lit_f32_vec(params)?);
            self.params_key = Some(key);
        }
        Ok(())
    }

    fn ensure_scale(&mut self, v: f32) {
        if self.scale_lit.is_none() || self.scale_val.to_bits() != v.to_bits()
        {
            self.scale_lit = Some(lit_f32_scalar(v));
            self.scale_val = v;
        }
    }
}

fn lit_f32_vec(data: &[f32]) -> Result<Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::F32, &[data.len()], bytes)?)
}

fn lit_i32_2d(data: &[i32], rows: usize, cols: usize) -> Result<Literal> {
    anyhow::ensure!(data.len() == rows * cols, "i32 literal shape mismatch");
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32, &[rows, cols], bytes)?)
}

fn lit_i32_1d(data: &[i32]) -> Result<Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8,
                                   data.len() * 4)
    };
    Ok(Literal::create_from_shape_and_untyped_data(
        ElementType::S32, &[data.len()], bytes)?)
}

fn lit_f32_scalar(v: f32) -> Literal {
    Literal::scalar(v)
}

/// Decode an f32 literal into a caller-owned buffer — the zero-copy
/// replacement for `Literal::to_vec`: no fresh `Vec`, the bytes land
/// straight in `dst` (PJRT's raw copy-out, same path `to_vec` uses
/// internally).
fn copy_f32_into(lit: &Literal, dst: &mut [f32]) -> Result<()> {
    let n = lit.element_count();
    anyhow::ensure!(n == dst.len(),
                    "literal holds {n} f32s, buffer holds {}", dst.len());
    lit.copy_raw_to::<f32>(dst)?;
    Ok(())
}

fn scalar_f32(lit: &Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

// ------------------------------------------------------------- steps  --

/// Compiled fwd+bwd step: (params, batch, loss_scale) -> loss/grads.
pub struct TrainStep {
    exe: PjRtLoadedExecutable,
    pub n_params: usize,
    pub batch: usize,
    pub seq: usize,
    pub key: String,
}

// SAFETY: a compiled PJRT executable is immutable after compilation and
// PJRT's CPU client supports concurrent `Execute` calls (that is how
// multi-device dispatch works); `TrainStep::run` takes `&self` and keeps
// no Rust-side mutable state.  The persistent collective pool shares one
// compiled step across its per-rank workers.
unsafe impl Send for TrainStep {}
unsafe impl Sync for TrainStep {}

impl TrainStep {
    /// Execute one micro-step (compatibility path): fresh literals and a
    /// fresh gradient `Vec`.  Delegates to [`Self::run_scratch`] through
    /// a throwaway scratch, so the two paths execute identical code and
    /// are bitwise-interchangeable.
    pub fn run(&self, params: &[f32], batch: &Batch, loss_scale: f32)
        -> Result<StepOutput> {
        let mut scratch = StepScratch::new();
        let mut grads = vec![0.0f32; self.n_params];
        let s = self.run_scratch(&mut scratch, params, 0, batch, loss_scale,
                                 &mut grads)?;
        Ok(StepOutput {
            loss: s.loss,
            mlm_loss: s.mlm_loss,
            nsp_loss: s.nsp_loss,
            mlm_acc: s.mlm_acc,
            grads,
            grad_norm: s.grad_norm,
        })
    }

    /// Execute one micro-step on the zero-copy hot path: the params
    /// literal and loss-scale scalar are recycled through `scratch` (see
    /// [`StepScratch`] for the `params_version` contract) and the
    /// gradients are decoded straight into `grads_out` — the steady
    /// state performs no gradient-sized allocation.
    pub fn run_scratch(&self, scratch: &mut StepScratch, params: &[f32],
                       params_version: u64, batch: &Batch, loss_scale: f32,
                       grads_out: &mut [f32]) -> Result<StepStats> {
        anyhow::ensure!(params.len() == self.n_params,
                        "params len {} != {}", params.len(), self.n_params);
        anyhow::ensure!(batch.batch == self.batch && batch.seq == self.seq,
                        "batch shape {}x{} != step {}x{}", batch.batch,
                        batch.seq, self.batch, self.seq);
        anyhow::ensure!(grads_out.len() == self.n_params,
                        "grads buffer {} != {}", grads_out.len(),
                        self.n_params);
        scratch.ensure_params(params, params_version)?;
        scratch.ensure_scale(loss_scale);
        let ids = lit_i32_2d(&batch.input_ids, self.batch, self.seq)?;
        let tts = lit_i32_2d(&batch.token_type_ids, self.batch, self.seq)?;
        let att = lit_i32_2d(&batch.attention_mask, self.batch, self.seq)?;
        let mlm = lit_i32_2d(&batch.mlm_labels, self.batch, self.seq)?;
        let nsp = lit_i32_1d(&batch.nsp_labels)?;
        let inputs: [&Literal; 7] = [
            scratch.params_lit.as_ref().expect("params literal cached"),
            &ids,
            &tts,
            &att,
            &mlm,
            &nsp,
            scratch.scale_lit.as_ref().expect("scale literal cached"),
        ];
        let result = self.exe.execute::<&Literal>(&inputs)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 6,
                        "train step returned {} outputs", parts.len());
        copy_f32_into(&parts[4], grads_out)?;
        Ok(StepStats {
            loss: scalar_f32(&parts[0])?,
            mlm_loss: scalar_f32(&parts[1])?,
            nsp_loss: scalar_f32(&parts[2])?,
            mlm_acc: scalar_f32(&parts[3])?,
            grad_norm: scalar_f32(&parts[5])?,
        })
    }
}

/// Compiled optimizer apply: (p, g, m, v, step, lr) -> (p', m', v').
pub struct ApplyStep {
    exe: PjRtLoadedExecutable,
    pub n_params: usize,
}

impl ApplyStep {
    /// Execute; overwrites params/m/v truly in place — the updated
    /// state is decoded back into the existing buffers, so an optimizer
    /// step allocates no fresh `Vec`s and the buffers never move or
    /// drift in length (asserted up front for all four vectors).
    pub fn run(&self, params: &mut Vec<f32>, grads: &[f32],
               m: &mut Vec<f32>, v: &mut Vec<f32>, step: f32, lr: f32)
               -> Result<()> {
        anyhow::ensure!(params.len() == self.n_params,
                        "params len {} != {}", params.len(), self.n_params);
        anyhow::ensure!(grads.len() == self.n_params,
                        "grads len {} != {}", grads.len(), self.n_params);
        anyhow::ensure!(m.len() == self.n_params && v.len() == self.n_params,
                        "optimizer state {}/{} != {}", m.len(), v.len(),
                        self.n_params);
        let inputs = [
            lit_f32_vec(params)?,
            lit_f32_vec(grads)?,
            lit_f32_vec(m)?,
            lit_f32_vec(v)?,
            lit_f32_scalar(step),
            lit_f32_scalar(lr),
        ];
        let result = self.exe.execute::<Literal>(&inputs)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 3,
                        "apply returned {} outputs", parts.len());
        copy_f32_into(&parts[0], params)?;
        copy_f32_into(&parts[1], m)?;
        copy_f32_into(&parts[2], v)?;
        Ok(())
    }
}

/// QA fine-tuning batch (paper §5.3 mechanism): question+context spans.
#[derive(Debug, Clone)]
pub struct QaBatch {
    pub batch: usize,
    pub seq: usize,
    pub input_ids: Vec<i32>,
    pub token_type_ids: Vec<i32>,
    pub attention_mask: Vec<i32>,
    pub start_positions: Vec<i32>,
    pub end_positions: Vec<i32>,
}

impl QaBatch {
    pub fn zeros(batch: usize, seq: usize) -> Self {
        Self {
            batch,
            seq,
            input_ids: vec![0; batch * seq],
            token_type_ids: vec![0; batch * seq],
            attention_mask: vec![0; batch * seq],
            start_positions: vec![0; batch],
            end_positions: vec![0; batch],
        }
    }
}

/// Output of one QA fine-tuning step.
#[derive(Debug, Clone)]
pub struct QaOutput {
    pub loss: f32,
    pub start_acc: f32,
    pub end_acc: f32,
    pub exact: f32,
    pub grads: Vec<f32>,
    pub grad_norm: f32,
}

/// Scalar outputs of the QA zero-copy path (gradients go to the
/// caller's buffer, mirroring [`StepStats`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct QaStats {
    pub loss: f32,
    pub start_acc: f32,
    pub end_acc: f32,
    pub exact: f32,
    pub grad_norm: f32,
}

/// Compiled QA fine-tuning step over the extended flat vector.
pub struct QaStep {
    exe: PjRtLoadedExecutable,
    pub n_params: usize,
    pub batch: usize,
    pub seq: usize,
}

impl QaStep {
    /// Compatibility path: fresh literals + fresh gradient `Vec`.
    pub fn run(&self, params_ft: &[f32], batch: &QaBatch, loss_scale: f32)
        -> Result<QaOutput> {
        let mut scratch = StepScratch::new();
        let mut grads = vec![0.0f32; self.n_params];
        let s = self.run_scratch(&mut scratch, params_ft, 0, batch,
                                 loss_scale, &mut grads)?;
        Ok(QaOutput {
            loss: s.loss,
            start_acc: s.start_acc,
            end_acc: s.end_acc,
            exact: s.exact,
            grads,
            grad_norm: s.grad_norm,
        })
    }

    /// Zero-copy path: same recycling contract as
    /// [`TrainStep::run_scratch`].
    pub fn run_scratch(&self, scratch: &mut StepScratch, params_ft: &[f32],
                       params_version: u64, batch: &QaBatch,
                       loss_scale: f32, grads_out: &mut [f32])
                       -> Result<QaStats> {
        anyhow::ensure!(params_ft.len() == self.n_params,
                        "ft params len {} != {}", params_ft.len(),
                        self.n_params);
        anyhow::ensure!(batch.batch == self.batch && batch.seq == self.seq);
        anyhow::ensure!(grads_out.len() == self.n_params,
                        "grads buffer {} != {}", grads_out.len(),
                        self.n_params);
        scratch.ensure_params(params_ft, params_version)?;
        scratch.ensure_scale(loss_scale);
        let ids = lit_i32_2d(&batch.input_ids, self.batch, self.seq)?;
        let tts = lit_i32_2d(&batch.token_type_ids, self.batch, self.seq)?;
        let att = lit_i32_2d(&batch.attention_mask, self.batch, self.seq)?;
        let sp = lit_i32_1d(&batch.start_positions)?;
        let ep = lit_i32_1d(&batch.end_positions)?;
        let inputs: [&Literal; 7] = [
            scratch.params_lit.as_ref().expect("params literal cached"),
            &ids,
            &tts,
            &att,
            &sp,
            &ep,
            scratch.scale_lit.as_ref().expect("scale literal cached"),
        ];
        let result = self.exe.execute::<&Literal>(&inputs)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 6);
        copy_f32_into(&parts[4], grads_out)?;
        Ok(QaStats {
            loss: scalar_f32(&parts[0])?,
            start_acc: scalar_f32(&parts[1])?,
            end_acc: scalar_f32(&parts[2])?,
            exact: scalar_f32(&parts[3])?,
            grad_norm: scalar_f32(&parts[5])?,
        })
    }
}

/// Compiled eval forward: (params, batch) -> (loss, mlm, nsp, acc).
pub struct ForwardStep {
    exe: PjRtLoadedExecutable,
    pub n_params: usize,
}

impl ForwardStep {
    pub fn run(&self, params: &[f32], batch: &Batch)
        -> Result<(f32, f32, f32, f32)> {
        let inputs = [
            lit_f32_vec(params)?,
            lit_i32_2d(&batch.input_ids, batch.batch, batch.seq)?,
            lit_i32_2d(&batch.token_type_ids, batch.batch, batch.seq)?,
            lit_i32_2d(&batch.attention_mask, batch.batch, batch.seq)?,
            lit_i32_2d(&batch.mlm_labels, batch.batch, batch.seq)?,
            lit_i32_1d(&batch.nsp_labels)?,
        ];
        let result = self.exe.execute::<Literal>(&inputs)?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        anyhow::ensure!(parts.len() == 4);
        Ok((
            scalar_f32(&parts[0])?,
            scalar_f32(&parts[1])?,
            scalar_f32(&parts[2])?,
            scalar_f32(&parts[3])?,
        ))
    }
}
