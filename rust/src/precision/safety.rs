//! Numerical-safety classification + graph rewriting (paper §4.2).
//!
//! "Typically in a computation graph, not all FP16 operators are
//! numerically safe... a *plus* operator is marked as safe while a
//! *power* or a *log* operator is considered numerically dangerous in
//! half precision. Automated mixed precision handles the categorization
//! of the numerical safety level through the rewriting of computation
//! graph."  This module implements that pass over an op-list IR:
//! allowlist ops run in f16, blocklist ops are pinned to f32, neutral
//! ops inherit from their inputs (the TF grappler/AMP inference rule),
//! and casts are inserted at dtype boundaries.

/// Operator kinds found in the BERT training graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    MatMul,
    Add,
    Mul,
    Sub,
    Tanh,
    Gelu,
    Softmax,
    Exp,
    Log,
    Pow,
    Div,
    Sqrt,
    Rsqrt,
    ReduceSum,
    ReduceMean,
    LayerNorm,
    Gather,
    Transpose,
    Reshape,
    Dropout,
    CrossEntropy,
}

/// AMP safety class.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Safety {
    /// Allowlist: numerically safe AND profits from f16 (TensorCore/MXU).
    Safe,
    /// Blocklist: dangerous in f16 (wide dynamic range / cancellation).
    Dangerous,
    /// Infer from inputs (shape/layout ops, cheap elementwise).
    Neutral,
}

/// The paper's categorization, extended to the full BERT op set.
pub fn classify(op: OpKind) -> Safety {
    use OpKind::*;
    match op {
        // allowlist: matmul-class ops are why AMP exists
        MatMul => Safety::Safe,
        // blocklist: exp/log/pow/softmax/norms/losses stay f32
        Exp | Log | Pow | Softmax | LayerNorm | CrossEntropy | ReduceSum
        | ReduceMean | Sqrt | Rsqrt | Div => Safety::Dangerous,
        // neutral: follow the data
        Add | Mul | Sub | Tanh | Gelu | Gather | Transpose | Reshape
        | Dropout => Safety::Neutral,
    }
}

/// One op in the linearized graph IR.
#[derive(Debug, Clone)]
pub struct GraphOp {
    pub name: String,
    pub kind: OpKind,
    /// Indices of producer ops (empty = graph input, treated as f16-able
    /// activations).
    pub inputs: Vec<usize>,
}

/// Result of the rewrite: per-op compute dtype + inserted cast count.
#[derive(Debug, Clone, PartialEq)]
pub struct DtypeAssignment {
    /// true = f16 compute, false = f32.
    pub f16: Vec<bool>,
    /// Number of cast nodes the rewrite inserted.
    pub casts_inserted: usize,
}

impl DtypeAssignment {
    pub fn count_f16(&self) -> usize {
        self.f16.iter().filter(|&&x| x).count()
    }
}

/// The AMP graph-rewriting pass: assign f16 to Safe ops, f32 to
/// Dangerous ops, and propagate through Neutral ops (a neutral op runs
/// in f16 iff ALL its inputs are f16 — the conservative grappler rule);
/// count the casts needed at every f16/f32 edge.
pub fn rewrite_graph(ops: &[GraphOp]) -> DtypeAssignment {
    let n = ops.len();
    let mut f16 = vec![false; n];
    // forward pass in topological (index) order
    for i in 0..n {
        f16[i] = match classify(ops[i].kind) {
            Safety::Safe => true,
            Safety::Dangerous => false,
            Safety::Neutral => {
                // graph inputs count as f16-able
                ops[i].inputs.iter().all(|&p| f16[p])
                    && !ops[i].inputs.is_empty()
                    || ops[i].inputs.is_empty()
            }
        };
    }
    // count boundary casts
    let mut casts = 0usize;
    for (i, op) in ops.iter().enumerate() {
        for &p in &op.inputs {
            if f16[p] != f16[i] {
                casts += 1;
            }
        }
    }
    DtypeAssignment { f16, casts_inserted: casts }
}

/// Build the linearized op-list of one BERT encoder layer (forward),
/// used by the amp-demo subcommand and the §4.2 tests.
pub fn bert_layer_graph() -> Vec<GraphOp> {
    use OpKind::*;
    let mut ops: Vec<GraphOp> = Vec::new();
    let mut add = |name: &str, kind, inputs: Vec<usize>| -> usize {
        ops.push(GraphOp { name: name.into(), kind, inputs });
        ops.len() - 1
    };
    let x = add("input", Reshape, vec![]);
    let q = add("q_proj", MatMul, vec![x]);
    let k = add("k_proj", MatMul, vec![x]);
    let v = add("v_proj", MatMul, vec![x]);
    let qk = add("qk_scores", MatMul, vec![q, k]);
    let sm = add("attn_softmax", Softmax, vec![qk]);
    let ctx = add("attn_context", MatMul, vec![sm, v]);
    let proj = add("attn_out_proj", MatMul, vec![ctx]);
    let res1 = add("residual1", Add, vec![x, proj]);
    let ln1 = add("layernorm1", LayerNorm, vec![res1]);
    let inter = add("intermediate", MatMul, vec![ln1]);
    let gelu = add("gelu", Gelu, vec![inter]);
    let out = add("output_proj", MatMul, vec![gelu]);
    let res2 = add("residual2", Add, vec![ln1, out]);
    let _ln2 = add("layernorm2", LayerNorm, vec![res2]);
    ops
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_examples_classified() {
        // §4.2: plus is safe(neutral-follow), power and log are dangerous.
        assert_eq!(classify(OpKind::Add), Safety::Neutral);
        assert_eq!(classify(OpKind::Pow), Safety::Dangerous);
        assert_eq!(classify(OpKind::Log), Safety::Dangerous);
        assert_eq!(classify(OpKind::MatMul), Safety::Safe);
    }

    #[test]
    fn bert_layer_assignment_structure() {
        let g = bert_layer_graph();
        let a = rewrite_graph(&g);
        let by_name = |n: &str| {
            let i = g.iter().position(|o| o.name == n).unwrap();
            a.f16[i]
        };
        // all matmuls in f16 (the TensorCore work)
        for n in ["q_proj", "k_proj", "v_proj", "qk_scores", "attn_context",
                  "attn_out_proj", "intermediate", "output_proj"] {
            assert!(by_name(n), "{n} should be f16");
        }
        // dangerous ops pinned to f32
        assert!(!by_name("attn_softmax"));
        assert!(!by_name("layernorm1"));
        assert!(!by_name("layernorm2"));
        // casts exist at the f16/f32 boundaries
        assert!(a.casts_inserted > 0);
    }

    #[test]
    fn neutral_follows_inputs() {
        use OpKind::*;
        let g = vec![
            GraphOp { name: "a".into(), kind: MatMul, inputs: vec![] },
            GraphOp { name: "b".into(), kind: Softmax, inputs: vec![0] },
            GraphOp { name: "add_ff".into(), kind: Add, inputs: vec![0, 0] },
            GraphOp { name: "add_fx".into(), kind: Add, inputs: vec![0, 1] },
        ];
        let a = rewrite_graph(&g);
        assert!(a.f16[2], "f16+f16 neutral stays f16");
        assert!(!a.f16[3], "f16+f32 neutral falls back to f32");
    }

    #[test]
    fn majority_of_bert_layer_runs_f16() {
        // The point of AMP: most of the layer's ops (and ~all FLOPs,
        // which live in the matmuls) end up in f16.
        let g = bert_layer_graph();
        let a = rewrite_graph(&g);
        assert!(a.count_f16() * 2 > g.len(), "{}/{}", a.count_f16(), g.len());
    }

    #[test]
    fn cast_count_is_edge_consistent() {
        let g = bert_layer_graph();
        let a = rewrite_graph(&g);
        let manual: usize = g
            .iter()
            .enumerate()
            .map(|(i, op)| {
                op.inputs.iter().filter(|&&p| a.f16[p] != a.f16[i]).count()
            })
            .sum();
        assert_eq!(a.casts_inserted, manual);
    }
}
