//! Dynamic loss scaling (paper §2.3 "Loss scaling", §4.2).
//!
//! The Apex `DynamicLossScaler` policy: multiply the loss by `scale`
//! before backward; after unscaling, if any gradient is non-finite the
//! step is SKIPPED and the scale halved; after `growth_interval`
//! consecutive good steps the scale doubles (up to a cap).  This keeps
//! the scale riding just under the overflow threshold, maximizing how
//! much of FP16's positive exponent range the gradients use.

/// Verdict for one training step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepVerdict {
    /// Gradients finite — apply the optimizer step.
    Apply,
    /// Overflow detected — skip the step, scale was reduced.
    Skip,
}

/// The scaler's complete serializable state (checkpoint v2 §scaler
/// section).  Restoring a scaler from this and feeding it the same
/// overflow history produces bit-identical scales and verdicts as one
/// that never stopped — the resume-exactness contract depends on the
/// growth streak (`good_steps`) surviving a save/load, not just the
/// scale itself.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ScalerState {
    pub scale: f64,
    pub growth_factor: f64,
    pub backoff_factor: f64,
    pub max_scale: f64,
    pub min_scale: f64,
    pub growth_interval: u64,
    pub good_steps: u64,
    pub total_steps: u64,
    pub skipped_steps: u64,
    pub growths: u64,
    pub backoffs: u64,
}

impl Default for ScalerState {
    fn default() -> Self {
        DynamicLossScaler::default().export()
    }
}

impl ScalerState {
    /// The state a v1 checkpoint implies: the saved scale under the
    /// trainer's stock policy with zeroed counters (the growth streak
    /// restarts — exactly what the legacy restore did).
    pub fn legacy(scale: f64) -> ScalerState {
        ScalerState {
            scale,
            ..DynamicLossScaler::new(65536.0)
                .with_growth_interval(200)
                .export()
        }
    }
}

/// Dynamic loss-scaler state machine.
#[derive(Debug, Clone)]
pub struct DynamicLossScaler {
    scale: f64,
    growth_factor: f64,
    backoff_factor: f64,
    growth_interval: usize,
    good_steps: usize,
    max_scale: f64,
    min_scale: f64,
    /// Counters for reporting.
    pub total_steps: usize,
    pub skipped_steps: usize,
    pub growths: usize,
    pub backoffs: usize,
}

impl Default for DynamicLossScaler {
    fn default() -> Self {
        Self::new(65536.0)
    }
}

impl DynamicLossScaler {
    /// Apex defaults: init 2^16, x2 growth every 2000 good steps, /2 on
    /// overflow.
    pub fn new(init_scale: f64) -> Self {
        assert!(init_scale >= 1.0);
        Self {
            scale: init_scale,
            growth_factor: 2.0,
            backoff_factor: 0.5,
            growth_interval: 2000,
            good_steps: 0,
            max_scale: 2.0f64.powi(24),
            min_scale: 1.0,
            total_steps: 0,
            skipped_steps: 0,
            growths: 0,
            backoffs: 0,
        }
    }

    /// Builder: growth interval (tests use small values).
    pub fn with_growth_interval(mut self, n: usize) -> Self {
        self.growth_interval = n.max(1);
        self
    }

    /// Current scale — feed this to the AOT train step's `loss_scale`
    /// input.
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// Record a step's overflow status; returns whether to apply or skip.
    pub fn update(&mut self, saw_overflow: bool) -> StepVerdict {
        self.total_steps += 1;
        if saw_overflow {
            self.skipped_steps += 1;
            self.backoffs += 1;
            self.good_steps = 0;
            self.scale =
                (self.scale * self.backoff_factor).max(self.min_scale);
            StepVerdict::Skip
        } else {
            self.good_steps += 1;
            if self.good_steps >= self.growth_interval {
                self.good_steps = 0;
                let next = self.scale * self.growth_factor;
                if next <= self.max_scale {
                    self.scale = next;
                    self.growths += 1;
                }
            }
            StepVerdict::Apply
        }
    }

    /// Export the complete state for checkpointing (see [`ScalerState`]).
    pub fn export(&self) -> ScalerState {
        ScalerState {
            scale: self.scale,
            growth_factor: self.growth_factor,
            backoff_factor: self.backoff_factor,
            max_scale: self.max_scale,
            min_scale: self.min_scale,
            growth_interval: self.growth_interval as u64,
            good_steps: self.good_steps as u64,
            total_steps: self.total_steps as u64,
            skipped_steps: self.skipped_steps as u64,
            growths: self.growths as u64,
            backoffs: self.backoffs as u64,
        }
    }

    /// Rebuild a scaler from exported state.  Values are taken verbatim
    /// (no asserts — the checkpoint layer has already CRC-validated the
    /// bytes; a scaler must never panic on a loadable file).
    pub fn from_state(s: &ScalerState) -> DynamicLossScaler {
        DynamicLossScaler {
            scale: s.scale,
            growth_factor: s.growth_factor,
            backoff_factor: s.backoff_factor,
            growth_interval: (s.growth_interval as usize).max(1),
            good_steps: s.good_steps as usize,
            max_scale: s.max_scale,
            min_scale: s.min_scale,
            total_steps: s.total_steps as usize,
            skipped_steps: s.skipped_steps as usize,
            growths: s.growths as usize,
            backoffs: s.backoffs as usize,
        }
    }

    /// Fraction of steps skipped so far.
    pub fn skip_rate(&self) -> f64 {
        if self.total_steps == 0 {
            0.0
        } else {
            self.skipped_steps as f64 / self.total_steps as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;
    use crate::util::Pcg64;

    #[test]
    fn overflow_halves_and_skips() {
        let mut s = DynamicLossScaler::new(1024.0);
        assert_eq!(s.update(true), StepVerdict::Skip);
        assert_eq!(s.scale(), 512.0);
        assert_eq!(s.skipped_steps, 1);
    }

    #[test]
    fn growth_after_interval() {
        let mut s = DynamicLossScaler::new(1024.0).with_growth_interval(3);
        for _ in 0..2 {
            assert_eq!(s.update(false), StepVerdict::Apply);
            assert_eq!(s.scale(), 1024.0);
        }
        s.update(false); // 3rd good step -> grow
        assert_eq!(s.scale(), 2048.0);
    }

    #[test]
    fn overflow_resets_growth_streak() {
        let mut s = DynamicLossScaler::new(1024.0).with_growth_interval(3);
        s.update(false);
        s.update(false);
        s.update(true); // streak broken, scale halved
        assert_eq!(s.scale(), 512.0);
        s.update(false);
        s.update(false);
        assert_eq!(s.scale(), 512.0); // only 2 good steps since overflow
        s.update(false);
        assert_eq!(s.scale(), 1024.0);
    }

    #[test]
    fn scale_never_leaves_bounds() {
        let mut s = DynamicLossScaler::new(2.0);
        for _ in 0..100 {
            s.update(true);
        }
        assert!(s.scale() >= 1.0);
        let mut s = DynamicLossScaler::new(65536.0).with_growth_interval(1);
        for _ in 0..100 {
            s.update(false);
        }
        assert!(s.scale() <= 2.0f64.powi(24));
    }

    #[test]
    fn prop_scale_positive_and_finite_under_random_history() {
        testkit::check(
            "scaler-invariant", 0x5CA1E, 64,
            |r: &mut Pcg64| {
                (0..200).map(|_| r.chance(0.1)).collect::<Vec<bool>>()
            },
            |history| {
                let mut s = DynamicLossScaler::new(65536.0)
                    .with_growth_interval(5);
                for &ov in history {
                    s.update(ov);
                }
                s.scale().is_finite() && s.scale() >= 1.0
                    && s.scale() <= 2.0f64.powi(24)
            },
        );
    }

    #[test]
    fn prop_export_import_is_future_exact() {
        // The checkpoint contract: splitting a run at ANY step k —
        // export the scaler, rebuild it from the state, continue — must
        // be indistinguishable (scales, verdicts, counters) from never
        // having stopped, including mid-growth-streak and mid-backoff.
        testkit::check(
            "scaler-resume-exact", 0xE5CA, 64,
            |r: &mut Pcg64| {
                let hist: Vec<bool> =
                    (0..120).map(|_| r.chance(0.15)).collect();
                let k = r.range_usize(0, hist.len() + 1);
                (hist, k)
            },
            |(hist, k)| {
                let mut a = DynamicLossScaler::new(4096.0)
                    .with_growth_interval(7);
                let mut b = DynamicLossScaler::new(4096.0)
                    .with_growth_interval(7);
                let mut verdicts_equal = true;
                for &ov in &hist[..*k] {
                    a.update(ov);
                    b.update(ov);
                }
                let mut b = DynamicLossScaler::from_state(&b.export());
                for &ov in &hist[*k..] {
                    verdicts_equal &= a.update(ov) == b.update(ov);
                }
                verdicts_equal
                    && a.scale().to_bits() == b.scale().to_bits()
                    && a.export() == b.export()
            },
        );
    }

    #[test]
    fn legacy_state_matches_trainer_stock_policy() {
        let s = ScalerState::legacy(1024.0);
        assert_eq!(s.scale, 1024.0);
        assert_eq!(s.growth_interval, 200);
        assert_eq!(s.good_steps, 0);
        assert_eq!(s.total_steps, 0);
        let sc = DynamicLossScaler::from_state(&s);
        assert_eq!(sc.scale(), 1024.0);
    }

    #[test]
    fn converges_under_threshold_model() {
        // Model a hard overflow threshold: overflow iff scale > 2^13.
        // The scaler must settle into oscillation just below it (within
        // one growth factor), not diverge or collapse.
        let mut s = DynamicLossScaler::new(65536.0).with_growth_interval(10);
        for _ in 0..500 {
            let ov = s.scale() > 8192.0;
            s.update(ov);
        }
        assert!(s.scale() <= 8192.0);
        assert!(s.scale() >= 2048.0, "collapsed to {}", s.scale());
        assert!(s.skip_rate() < 0.2, "skip rate {}", s.skip_rate());
    }
}
