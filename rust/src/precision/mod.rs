//! Automated Mixed Precision engine (paper §2.3, §4.2).
//!
//! Three cooperating pieces, exactly as in Apex/AMP:
//!
//! * [`safety`] — the numerical-safety categorization of graph operators
//!   (safe / dangerous / neutral) and the graph-rewriting pass that
//!   assigns a compute dtype to every op (the paper's example: `plus` is
//!   safe, `power`/`log` are dangerous);
//! * [`loss_scale`] — the dynamic loss-scaling state machine: grow the
//!   scale on a streak of finite steps, back off on overflow, skip the
//!   optimizer step when gradients blew up;
//! * overflow detection over real gradient buffers via the from-scratch
//!   [`crate::half`] f16 semantics.

pub mod loss_scale;
pub mod safety;

pub use loss_scale::{DynamicLossScaler, ScalerState, StepVerdict};
pub use safety::{classify, rewrite_graph, DtypeAssignment, OpKind, Safety};

/// Scan a gradient buffer for non-finite values (overflow check after
/// unscaling — cheap single pass, the paper's "check before update").
pub fn has_nonfinite(grads: &[f32]) -> bool {
    grads.iter().any(|g| !g.is_finite())
}

/// Fraction of gradient values that would flush to zero if cast to f16
/// at the given loss scale — the §2.3 diagnostic the scaler exists to fix.
pub fn f16_zero_fraction(grads: &[f32], scale: f32) -> f64 {
    if grads.is_empty() {
        return 0.0;
    }
    let zeroed = grads
        .iter()
        .filter(|&&g| {
            g != 0.0
                && matches!(crate::half::cast_fate(g * scale),
                            crate::half::CastFate::Zero)
        })
        .count();
    zeroed as f64 / grads.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nonfinite_detection() {
        assert!(!has_nonfinite(&[1.0, -2.0, 0.0]));
        assert!(has_nonfinite(&[1.0, f32::NAN]));
        assert!(has_nonfinite(&[f32::INFINITY]));
    }

    #[test]
    fn scaling_reduces_zero_fraction() {
        let grads: Vec<f32> = (0..1000).map(|i| 1e-11 * (i as f32 + 1.0))
            .collect();
        let unscaled = f16_zero_fraction(&grads, 1.0);
        let scaled = f16_zero_fraction(&grads, 65536.0);
        assert!(unscaled > 0.9, "{unscaled}");
        assert!(scaled < 0.1, "{scaled}");
    }
}
