//! Typed run configuration assembled from CLI + TOML (paper Tables 1/2/6).

use super::toml::TomlDoc;
use crate::collectives::pool::{CommMode, IntraNodeMode,
                               DEFAULT_CHUNK_ELEMS};
use crate::grad::sparsify::Sparsify;
use crate::topology::Topology;

/// Training hyper-parameters (per-phase values live in `phases.rs`).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model preset name (must exist in the AOT manifest).
    pub preset: String,
    /// Artifact variant: "fused_bf16" (optimized) .. "unfused_f32".
    pub variant: String,
    /// Optimizer: "lamb" | "adam".
    pub optimizer: String,
    /// Base learning rate (paper Table 6: 1e-4).
    pub lr: f64,
    /// Linear warmup steps before constant/decay.
    pub warmup_steps: usize,
    /// Gradient accumulation steps k (paper §4.4: 4 for the headline run).
    pub accum_steps: usize,
    /// Overlap backward with bucketed allreduce (paper Fig. 2).
    pub overlap: bool,
    /// Ship ring-allreduce payloads as IEEE f16 (paper §4.4 exchanges
    /// FP16 gradients): halves wire bytes at one round-to-nearest-even
    /// per hop.  Replicas stay bitwise identical; absolute gradient
    /// values differ from the f32 wire by ~2^-11 relative.
    pub grad_wire_f16: bool,
    /// How bucket allreduces travel the cluster (paper §4.4 resource
    /// separation): `flat` = one world-sized ring, `hierarchical` =
    /// PCIe leader-accumulate + network leader ring + PCIe broadcast,
    /// `auto` = hierarchical whenever the topology has multiple machines
    /// AND multiple GPUs per machine.
    pub comm_mode: CommMode,
    /// Intra-node schedule of the hierarchical exchange: `serial` =
    /// the (g-1) serialized whole-bucket leader transfers each way,
    /// `ring` = the chunked pipelined member chain (per-member
    /// transfers overlap; the inter-node ring starts on chunk 0 while
    /// chunk 1 is still gathering), `rs` = the bandwidth-optimal
    /// 2-level reduce-scatter (intra reduce-scatter, per-shard
    /// cross-machine rings, intra allgather — `O(n/g)` bytes per link),
    /// `auto` = ring whenever the hierarchy resolves (CLI
    /// `--intra-node`).
    pub intra_node: IntraNodeMode,
    /// Chunk size (elements) of the pipelined intra-node exchange (CLI
    /// `--chunk-elems`); values larger than a bucket degrade to one
    /// chunk per bucket (the serialized schedule's granularity).
    pub chunk_elems: usize,
    /// Top-k gradient sparsification of the NETWORK-crossing rings (CLI
    /// `--sparsify`, `none` | `topk:RATIO`): each cross-machine hop
    /// ships only the `ceil(ratio * len)` largest-magnitude entries of
    /// its segment as index/value pairs, and every rank folds the
    /// dropped residual into its next step via a local error-feedback
    /// accumulator.  PCIe links always stay dense; single-machine
    /// topologies ignore the knob entirely.
    pub sparsify: Sparsify,
    /// Gradient bucket size threshold in elements (DDP-style).
    pub bucket_elems: usize,
    /// Batch-prefetch ring depth per rank (paper §4.1: input prep must
    /// overlap training): one long-lived producer thread per rank keeps
    /// up to this many masked batches ready in reusable buffers.  `2` =
    /// classic double buffering (the default); `0` disables the
    /// producers and builds batches synchronously on the compute
    /// workers (bitwise-identical results, only the timing differs).
    pub prefetch_depth: usize,
    /// Total optimizer steps to run.
    pub steps: usize,
    /// Steps between periodic async checkpoints (0 = off).  Snapshots
    /// are taken at optimizer-step boundaries into recycled buffers;
    /// the atomic write + rotation run on a background thread (CLI
    /// `--save-every`, with `--ckpt-dir` naming the rotation dir).
    pub save_every: usize,
    /// Rotation depth for periodic checkpoints: keep the newest K
    /// `ckpt-*.bckp` files (CLI `--keep-last`).
    pub keep_last: usize,
    /// Socket-transport receive timeout in seconds (CLI `--net-timeout`):
    /// how long a comm worker waits on a quiet peer link before
    /// surfacing a transport timeout instead of hanging.  Only consulted
    /// when the run uses `--listen/--connect/--rendezvous`; the
    /// in-process transport never times out.  `<= 0` disables the
    /// timeout (wait forever).
    pub net_timeout_s: f64,
    /// Shared secret authenticating socket handshakes (CLI `--net-key`):
    /// when non-empty, every link carries a keyed MAC over its handshake
    /// plus a per-run/per-generation nonce, and unauthenticated or
    /// foreign peers are rejected at accept time.  Empty (the default)
    /// keeps the v1 unauthenticated handshake.  Every process in the
    /// world must agree on the key.
    pub net_key: String,
    /// Connect-side dial attempts before giving up (CLI `--net-retries`):
    /// `0` retries on a deterministic bounded-exponential backoff until
    /// the setup deadline; `N > 0` caps the attempts.
    pub net_retries: u32,
    /// Base backoff between dial attempts, milliseconds (CLI
    /// `--net-backoff-ms`): doubles per attempt, capped at 500 ms.
    pub net_backoff_ms: u64,
    /// Seconds the restart supervisor keeps the rendezvous open for lost
    /// ranks to rejoin at the SAME world size before degrading to a
    /// shrink (CLI `--rejoin-window`; 0 disables grow-back and restarts
    /// straight into the shrink path).  Only meaningful with
    /// `--rendezvous` and `--max-restarts > 0`.
    pub rejoin_window_s: f64,
    /// Initial dynamic loss scale (paper §4.2).
    pub init_loss_scale: f64,
    /// RNG seed for data order + masking.
    pub seed: u64,
    /// Steps between metric log lines.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            preset: "bert-tiny".into(),
            variant: "fused_f32".into(),
            optimizer: "lamb".into(),
            lr: 1e-4,
            warmup_steps: 10,
            accum_steps: 4,
            overlap: true,
            grad_wire_f16: false,
            comm_mode: CommMode::Auto,
            intra_node: IntraNodeMode::Auto,
            chunk_elems: DEFAULT_CHUNK_ELEMS,
            sparsify: Sparsify::None,
            bucket_elems: 1 << 20,
            prefetch_depth: 2,
            steps: 100,
            save_every: 0,
            keep_last: 3,
            net_timeout_s: 30.0,
            net_key: String::new(),
            net_retries: 0,
            net_backoff_ms: 20,
            rejoin_window_s: 0.0,
            init_loss_scale: 65536.0,
            seed: 42,
            log_every: 10,
        }
    }
}

/// Cluster description (paper Table 1).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Topology in the paper's "<X>M<Y>G" encoding.
    pub topo: Topology,
    /// Inter-node network bandwidth, bits per second (paper: 10 Gb/s).
    pub network_bps: f64,
    /// Intra-node PCIe bandwidth, bits per second (paper: 64 Gb/s).
    pub pcie_bps: f64,
    /// Per-message network latency, seconds.
    pub net_latency_s: f64,
    /// Per-message PCIe latency, seconds.
    pub pcie_latency_s: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            topo: Topology::parse("1M2G").unwrap(),
            network_bps: 10e9,
            pcie_bps: 64e9,
            net_latency_s: 50e-6,
            pcie_latency_s: 5e-6,
        }
    }
}

/// Data pipeline configuration (paper §3.1, §4.1).
#[derive(Debug, Clone)]
pub struct DataConfig {
    /// Directory of bshard files.
    pub shard_dir: String,
    /// Per-GPU micro-batch size.
    pub micro_batch: usize,
    /// Sequence length (128 phase 1 / 512 phase 2).
    pub seq_len: usize,
    /// MLM mask probability (paper: 0.15).
    pub mask_prob: f64,
    /// Max predictions per sequence (paper Table 6: 20 @128, 80 @512).
    pub max_predictions: usize,
    /// Vocabulary size (must match the model preset).
    pub vocab_size: usize,
}

impl Default for DataConfig {
    fn default() -> Self {
        Self {
            shard_dir: "data/shards".into(),
            micro_batch: 8,
            seq_len: 128,
            mask_prob: 0.15,
            max_predictions: 20,
            vocab_size: 8192,
        }
    }
}

/// Top-level run config.
#[derive(Debug, Clone, Default)]
pub struct RunConfig {
    pub train: TrainConfig,
    pub cluster: ClusterConfig,
    pub data: DataConfig,
    /// Artifacts directory holding manifest.json.
    pub artifacts_dir: String,
}

impl RunConfig {
    /// Merge a TOML document over the defaults.
    pub fn from_toml(doc: &TomlDoc) -> anyhow::Result<RunConfig> {
        let mut c = RunConfig::default();
        c.artifacts_dir = doc.str("artifacts_dir", "artifacts");

        c.train.preset = doc.str("train.preset", &c.train.preset);
        c.train.variant = doc.str("train.variant", &c.train.variant);
        c.train.optimizer = doc.str("train.optimizer", &c.train.optimizer);
        c.train.lr = doc.float("train.lr", c.train.lr);
        c.train.warmup_steps =
            doc.int("train.warmup_steps", c.train.warmup_steps as i64) as usize;
        c.train.accum_steps =
            doc.int("train.accum_steps", c.train.accum_steps as i64) as usize;
        c.train.overlap = doc.bool("train.overlap", c.train.overlap);
        c.train.grad_wire_f16 =
            doc.bool("train.grad_wire_f16", c.train.grad_wire_f16);
        let comm = doc.str("train.comm_mode", &c.train.comm_mode.to_string());
        c.train.comm_mode = CommMode::parse(&comm)
            .map_err(|e| anyhow::anyhow!("train.comm_mode: {e}"))?;
        let intra =
            doc.str("train.intra_node", &c.train.intra_node.to_string());
        c.train.intra_node = IntraNodeMode::parse(&intra)
            .map_err(|e| anyhow::anyhow!("train.intra_node: {e}"))?;
        c.train.chunk_elems =
            doc.int("train.chunk_elems", c.train.chunk_elems as i64) as usize;
        let sparsify =
            doc.str("train.sparsify", &c.train.sparsify.to_string());
        c.train.sparsify = Sparsify::parse(&sparsify)
            .map_err(|e| anyhow::anyhow!("train.sparsify: {e}"))?;
        c.train.bucket_elems =
            doc.int("train.bucket_elems", c.train.bucket_elems as i64) as usize;
        c.train.prefetch_depth =
            doc.int("train.prefetch_depth",
                    c.train.prefetch_depth as i64) as usize;
        c.train.steps = doc.int("train.steps", c.train.steps as i64) as usize;
        c.train.save_every =
            doc.int("train.save_every", c.train.save_every as i64) as usize;
        c.train.keep_last =
            doc.int("train.keep_last", c.train.keep_last as i64) as usize;
        c.train.net_timeout_s =
            doc.float("train.net_timeout_s", c.train.net_timeout_s);
        c.train.net_key = doc.str("train.net_key", &c.train.net_key);
        c.train.net_retries =
            doc.int("train.net_retries", c.train.net_retries as i64) as u32;
        c.train.net_backoff_ms =
            doc.int("train.net_backoff_ms",
                    c.train.net_backoff_ms as i64) as u64;
        c.train.rejoin_window_s =
            doc.float("train.rejoin_window_s", c.train.rejoin_window_s);
        c.train.init_loss_scale =
            doc.float("train.init_loss_scale", c.train.init_loss_scale);
        c.train.seed = doc.int("train.seed", c.train.seed as i64) as u64;
        c.train.log_every =
            doc.int("train.log_every", c.train.log_every as i64) as usize;

        let topo = doc.str("cluster.topo", "1M2G");
        c.cluster.topo = Topology::parse(&topo)
            .map_err(|e| anyhow::anyhow!("cluster.topo: {e}"))?;
        c.cluster.network_bps =
            doc.float("cluster.network_gbps", c.cluster.network_bps / 1e9) * 1e9;
        c.cluster.pcie_bps =
            doc.float("cluster.pcie_gbps", c.cluster.pcie_bps / 1e9) * 1e9;
        c.cluster.net_latency_s =
            doc.float("cluster.net_latency_us",
                      c.cluster.net_latency_s * 1e6) / 1e6;
        c.cluster.pcie_latency_s =
            doc.float("cluster.pcie_latency_us",
                      c.cluster.pcie_latency_s * 1e6) / 1e6;

        c.data.shard_dir = doc.str("data.shard_dir", &c.data.shard_dir);
        c.data.micro_batch =
            doc.int("data.micro_batch", c.data.micro_batch as i64) as usize;
        c.data.seq_len = doc.int("data.seq_len", c.data.seq_len as i64) as usize;
        c.data.mask_prob = doc.float("data.mask_prob", c.data.mask_prob);
        c.data.max_predictions =
            doc.int("data.max_predictions",
                    c.data.max_predictions as i64) as usize;
        c.data.vocab_size =
            doc.int("data.vocab_size", c.data.vocab_size as i64) as usize;
        Ok(c)
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.train.accum_steps >= 1, "accum_steps must be >= 1");
        anyhow::ensure!(self.train.bucket_elems >= 1,
                        "bucket_elems must be >= 1");
        anyhow::ensure!(self.train.chunk_elems >= 1,
                        "chunk_elems must be >= 1");
        anyhow::ensure!(self.train.steps >= 1, "steps must be >= 1");
        anyhow::ensure!(self.data.micro_batch >= 1, "micro_batch must be >= 1");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.data.mask_prob),
            "mask_prob must be in [0,1]"
        );
        anyhow::ensure!(self.train.init_loss_scale >= 1.0,
                        "init_loss_scale must be >= 1");
        anyhow::ensure!(self.train.keep_last >= 1,
                        "keep_last must be >= 1");
        anyhow::ensure!(
            matches!(self.train.optimizer.as_str(), "lamb" | "adam"),
            "optimizer must be lamb or adam"
        );
        anyhow::ensure!(self.train.net_key.len() <= 32,
                        "net_key must be at most 32 bytes");
        anyhow::ensure!(self.train.net_backoff_ms >= 1,
                        "net_backoff_ms must be >= 1");
        anyhow::ensure!(self.train.rejoin_window_s >= 0.0,
                        "rejoin_window_s must be >= 0");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_overrides_defaults() {
        let doc = TomlDoc::parse(
            "[train]\nsteps = 7\nlr = 0.5\noverlap = false\n\
             grad_wire_f16 = true\ncomm_mode = \"hierarchical\"\n\
             prefetch_depth = 4\nsave_every = 25\nkeep_last = 5\n\
             [cluster]\ntopo = \"2M4G\"\nnetwork_gbps = 25.0\n\
             [data]\nseq_len = 512\n",
        ).unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.train.steps, 7);
        assert_eq!(c.train.lr, 0.5);
        assert_eq!(c.train.save_every, 25);
        assert_eq!(c.train.keep_last, 5);
        // checkpointing defaults: periodic saves off, keep 3 on rotation
        assert_eq!(RunConfig::default().train.save_every, 0);
        assert_eq!(RunConfig::default().train.keep_last, 3);
        assert!(!c.train.overlap);
        assert!(c.train.grad_wire_f16);
        assert_eq!(c.train.prefetch_depth, 4);
        // default is double buffering
        assert_eq!(RunConfig::default().train.prefetch_depth, 2);
        assert_eq!(c.train.comm_mode, CommMode::Hierarchical);
        assert!(c.train.comm_mode.resolves_hierarchical(&c.cluster.topo));
        assert_eq!(c.cluster.topo.machines, 2);
        assert_eq!(c.cluster.topo.gpus_per_machine, 4);
        assert_eq!(c.cluster.network_bps, 25e9);
        assert_eq!(c.data.seq_len, 512);
    }

    #[test]
    fn bad_topology_is_error() {
        let doc = TomlDoc::parse("[cluster]\ntopo = \"banana\"\n").unwrap();
        assert!(RunConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn intra_node_knobs_parse_and_validate() {
        let doc = TomlDoc::parse(
            "[train]\nintra_node = \"serial\"\nchunk_elems = 4096\n",
        ).unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.train.intra_node, IntraNodeMode::Serial);
        assert_eq!(c.train.chunk_elems, 4096);
        // the 2-level reduce-scatter schedule is a first-class spelling
        let rs = TomlDoc::parse("[train]\nintra_node = \"rs\"\n").unwrap();
        let c_rs = RunConfig::from_toml(&rs).unwrap();
        assert_eq!(c_rs.train.intra_node, IntraNodeMode::ReduceScatter);
        // defaults: pipelined chain at DEFAULT_CHUNK_ELEMS
        let d = RunConfig::default();
        assert_eq!(d.train.intra_node, IntraNodeMode::Auto);
        assert_eq!(d.train.chunk_elems, DEFAULT_CHUNK_ELEMS);
        // bad spellings fail loudly
        let bad = TomlDoc::parse("[train]\nintra_node = \"tree\"\n").unwrap();
        let err = RunConfig::from_toml(&bad).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("intra_node"));
        // chunk_elems = 0 is rejected
        let mut c = RunConfig::default();
        c.train.chunk_elems = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn sparsify_knob_parses_and_validates() {
        let doc =
            TomlDoc::parse("[train]\nsparsify = \"topk:0.01\"\n").unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.train.sparsify, Sparsify::TopK(0.01));
        c.validate().unwrap();
        // default: dense everywhere
        assert_eq!(RunConfig::default().train.sparsify, Sparsify::None);
        // the exactness spelling is first-class
        let one = TomlDoc::parse("[train]\nsparsify = \"topk:1.0\"\n")
            .unwrap();
        let c = RunConfig::from_toml(&one).unwrap();
        assert_eq!(c.train.sparsify, Sparsify::TopK(1.0));
        // bad spellings and out-of-range ratios fail loudly
        for bad in ["dense", "topk:0", "topk:1.5", "topk:nan"] {
            let doc = TomlDoc::parse(
                &format!("[train]\nsparsify = \"{bad}\"\n")).unwrap();
            let err = RunConfig::from_toml(&doc).map(|_| ()).unwrap_err();
            assert!(err.to_string().contains("sparsify"), "{bad}: {err}");
        }
    }

    #[test]
    fn net_timeout_knob_parses() {
        let doc =
            TomlDoc::parse("[train]\nnet_timeout_s = 2.5\n").unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.train.net_timeout_s, 2.5);
        // default: 30 s; <= 0 (wait forever) still validates — the
        // knob only matters for socket runs.
        assert_eq!(RunConfig::default().train.net_timeout_s, 30.0);
        let mut c = RunConfig::default();
        c.train.net_timeout_s = 0.0;
        c.validate().unwrap();
    }

    #[test]
    fn rejoin_and_auth_knobs_parse_and_validate() {
        let doc = TomlDoc::parse(
            "[train]\nnet_key = \"sekrit\"\nnet_retries = 5\n\
             net_backoff_ms = 40\nrejoin_window_s = 15.0\n",
        ).unwrap();
        let c = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(c.train.net_key, "sekrit");
        assert_eq!(c.train.net_retries, 5);
        assert_eq!(c.train.net_backoff_ms, 40);
        assert_eq!(c.train.rejoin_window_s, 15.0);
        c.validate().unwrap();
        // defaults: unauthenticated, retry-until-deadline, no grow-back
        let d = RunConfig::default();
        assert_eq!(d.train.net_key, "");
        assert_eq!(d.train.net_retries, 0);
        assert_eq!(d.train.net_backoff_ms, 20);
        assert_eq!(d.train.rejoin_window_s, 0.0);
        // over-long keys and degenerate backoff are rejected
        let mut c = RunConfig::default();
        c.train.net_key = "k".repeat(33);
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.train.net_backoff_ms = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.train.rejoin_window_s = -1.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn bad_comm_mode_is_error() {
        let doc = TomlDoc::parse("[train]\ncomm_mode = \"rings\"\n").unwrap();
        let err = RunConfig::from_toml(&doc).map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("comm_mode"));
        // default is auto
        assert_eq!(RunConfig::default().train.comm_mode, CommMode::Auto);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut c = RunConfig::default();
        c.train.accum_steps = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.data.mask_prob = 1.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.train.optimizer = "sgd9000".into();
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.train.bucket_elems = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.train.keep_last = 0;
        assert!(c.validate().is_err());
    }
}
