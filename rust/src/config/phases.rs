//! The paper's two-phase pretraining schedule (§3.3, Table 6).
//!
//! Phase 1: seq 128, 20 predictions/seq, global batch 4096, 36 epochs.
//! Phase 2: seq 512, 80 predictions/seq, global batch 2048, 4 epochs
//! (the paper needed 6 due to a convergence issue — both are encoded).

/// One pretraining phase (a row of Table 6).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseConfig {
    pub name: &'static str,
    /// Per-GPU sentences per micro-batch (Table 6 "Sentences (S)").
    pub sentences_per_gpu: usize,
    /// Sequence length.
    pub seq_len: usize,
    /// Max MLM predictions per sequence.
    pub predictions_per_seq: usize,
    /// Global (cluster-wide, post-accumulation) batch size.
    pub global_batch: usize,
    /// Learning rate.
    pub lr: f64,
    /// Epochs in this phase.
    pub epochs: usize,
    /// Paper-reported wall-clock per epoch on 32M8G (hours).
    pub paper_epoch_hours: f64,
}

/// The full two-phase schedule.
#[derive(Debug, Clone)]
pub struct TwoPhaseSchedule {
    pub phase1: PhaseConfig,
    pub phase2: PhaseConfig,
}

impl TwoPhaseSchedule {
    /// The paper's exact Table 6 settings.
    pub fn paper() -> Self {
        Self {
            phase1: PhaseConfig {
                name: "phase1",
                sentences_per_gpu: 32,
                seq_len: 128,
                predictions_per_seq: 20,
                global_batch: 4096,
                lr: 1e-4,
                epochs: 36,
                paper_epoch_hours: 6.0,
            },
            phase2: PhaseConfig {
                name: "phase2",
                sentences_per_gpu: 4,
                seq_len: 512,
                predictions_per_seq: 80,
                global_batch: 2048,
                lr: 1e-4,
                epochs: 4, // ideal; the paper ran 6 (convergence issue, §5.2)
                paper_epoch_hours: 16.0,
            },
        }
    }

    /// Scale the schedule down for a testbed run: keep the *ratios*
    /// (seq 128 -> 512, predictions 20 -> 80, batch 2:1) but shrink the
    /// batch and replace epochs with explicit step counts.
    pub fn scaled(micro_batch: usize, phase1_steps: usize,
                  phase2_steps: usize) -> (PhaseConfig, PhaseConfig, usize, usize) {
        let p = Self::paper();
        let phase1 = PhaseConfig {
            sentences_per_gpu: micro_batch,
            global_batch: micro_batch * 4,
            ..p.phase1
        };
        let phase2 = PhaseConfig {
            sentences_per_gpu: (micro_batch / 8).max(1),
            global_batch: (micro_batch / 8).max(1) * 4,
            ..p.phase2
        };
        (phase1, phase2, phase1_steps, phase2_steps)
    }

    /// Total epochs (paper: 36 + 4 = 40).
    pub fn total_epochs(&self) -> usize {
        self.phase1.epochs + self.phase2.epochs
    }

    /// Fraction of epochs in phase 1 (paper: 90%).
    pub fn phase1_fraction(&self) -> f64 {
        self.phase1.epochs as f64 / self.total_epochs() as f64
    }

    /// Paper-reported total training days on 32M8G.
    pub fn paper_total_days(&self) -> f64 {
        (self.phase1.epochs as f64 * self.phase1.paper_epoch_hours
            + self.phase2.epochs as f64 * self.phase2.paper_epoch_hours)
            / 24.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_schedule_matches_table6() {
        let s = TwoPhaseSchedule::paper();
        assert_eq!(s.phase1.seq_len, 128);
        assert_eq!(s.phase2.seq_len, 512);
        assert_eq!(s.phase1.predictions_per_seq, 20);
        assert_eq!(s.phase2.predictions_per_seq, 80);
        assert_eq!(s.phase1.global_batch, 4096);
        assert_eq!(s.phase2.global_batch, 2048);
        assert_eq!(s.total_epochs(), 40);
        assert!((s.phase1_fraction() - 0.9).abs() < 1e-9);
    }

    #[test]
    fn paper_days_are_about_twelve() {
        // 36*6h + 4*16h = 280h = 11.67 days — the paper's "12 days".
        let days = TwoPhaseSchedule::paper().paper_total_days();
        assert!((days - 11.67).abs() < 0.1, "{days}");
    }

    #[test]
    fn scaled_preserves_ratios() {
        let (p1, p2, _, _) = TwoPhaseSchedule::scaled(8, 100, 20);
        assert_eq!(p1.seq_len, 128);
        assert_eq!(p2.seq_len, 512);
        assert_eq!(p1.sentences_per_gpu, 8);
        assert_eq!(p2.sentences_per_gpu, 1);
        assert_eq!(p1.predictions_per_seq, 20);
        assert_eq!(p2.predictions_per_seq, 80);
    }
}
