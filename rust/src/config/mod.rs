//! Configuration system: a TOML-subset parser plus the typed configs for
//! model / training / cluster / data (paper Tables 1, 2, 6).
//!
//! The parser (`toml.rs`) covers the subset real config files need:
//! `[section]` and `[section.sub]` headers, `key = value` with strings,
//! integers, floats, booleans, and homogeneous inline arrays, plus `#`
//! comments.  Substrate: the `toml` crate is unavailable offline.

pub mod phases;
pub mod toml;
pub mod types;

pub use phases::{PhaseConfig, TwoPhaseSchedule};
pub use toml::TomlDoc;
pub use types::{ClusterConfig, DataConfig, RunConfig, TrainConfig};
