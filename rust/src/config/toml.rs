//! TOML-subset parser (substrate: `toml`/`serde` unavailable offline).
//!
//! Supported: `[table]` / `[a.b]` headers, `key = "str" | 123 | 1.5 |
//! true | [1, 2, 3]`, `#` comments, blank lines.  Keys are flattened to
//! dotted paths (`section.key`).  This covers every config file shipped
//! in `examples/` and `rust/tests/`.

use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

#[derive(thiserror::Error, Debug)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

/// A parsed document: dotted-path -> value.
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut prefix = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(TomlError { line: ln + 1,
                                           msg: "unterminated header".into() });
                }
                prefix = line[1..line.len() - 1].trim().to_string();
                if prefix.is_empty() {
                    return Err(TomlError { line: ln + 1,
                                           msg: "empty table name".into() });
                }
                continue;
            }
            let eq = line.find('=').ok_or(TomlError {
                line: ln + 1,
                msg: "expected key = value".into(),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(TomlError { line: ln + 1, msg: "empty key".into() });
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(|msg| {
                TomlError { line: ln + 1, msg }
            })?;
            let full = if prefix.is_empty() {
                key.to_string()
            } else {
                format!("{prefix}.{key}")
            };
            doc.values.insert(full, val);
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<TomlDoc> {
        let text = std::fs::read_to_string(path)?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        match self.values.get(key) {
            Some(TomlValue::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }

    pub fn int(&self, key: &str, default: i64) -> i64 {
        match self.values.get(key) {
            Some(TomlValue::Int(i)) => *i,
            Some(TomlValue::Float(f)) => *f as i64,
            _ => default,
        }
    }

    pub fn float(&self, key: &str, default: f64) -> f64 {
        match self.values.get(key) {
            Some(TomlValue::Float(f)) => *f,
            Some(TomlValue::Int(i)) => *i as f64,
            _ => default,
        }
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.values.get(key) {
            Some(TomlValue::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(unescape(body)));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?;
        let body = body.trim();
        if body.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let mut out = Vec::new();
        for item in split_top_level(body) {
            out.push(parse_value(item.trim())?);
        }
        return Ok(TomlValue::Array(out));
    }
    // number: int if no '.', 'e', or 'E'
    let cleaned = s.replace('_', "");
    if cleaned.contains(['.', 'e', 'E']) {
        cleaned.parse::<f64>().map(TomlValue::Float)
            .map_err(|_| format!("bad float '{s}'"))
    } else {
        cleaned.parse::<i64>().map(TomlValue::Int)
            .map_err(|_| format!("bad integer '{s}'"))
    }
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run config
name = "phase1"            # inline comment
[train]
steps = 100
lr = 1e-4
overlap = true
accum = 4
[cluster]
topo = "32M8G"
bandwidths = [10.0, 64.0]
[cluster.net]
latency_us = 50
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(d.str("name", ""), "phase1");
        assert_eq!(d.int("train.steps", 0), 100);
        assert!((d.float("train.lr", 0.0) - 1e-4).abs() < 1e-12);
        assert!(d.bool("train.overlap", false));
        assert_eq!(d.str("cluster.topo", ""), "32M8G");
        assert_eq!(d.int("cluster.net.latency_us", 0), 50);
        match d.get("cluster.bandwidths") {
            Some(TomlValue::Array(a)) => assert_eq!(a.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_for_missing_keys() {
        let d = TomlDoc::parse("").unwrap();
        assert_eq!(d.int("nope", 7), 7);
        assert_eq!(d.str("nope", "x"), "x");
    }

    #[test]
    fn int_float_coercion() {
        let d = TomlDoc::parse("a = 3\nb = 2.5").unwrap();
        assert_eq!(d.float("a", 0.0), 3.0);
        assert_eq!(d.int("b", 0), 2);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let d = TomlDoc::parse(r##"k = "a#b" # real comment"##).unwrap();
        assert_eq!(d.str("k", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn escapes_in_strings() {
        let d = TomlDoc::parse(r#"k = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(d.str("k", ""), "a\nb\t\"c\"");
    }

    #[test]
    fn underscore_digit_separators() {
        let d = TomlDoc::parse("n = 1_000_000").unwrap();
        assert_eq!(d.int("n", 0), 1_000_000);
    }
}
