//! Minimal CLI argument parser (substrate: `clap` unavailable offline).
//!
//! Grammar: `bertdist <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]`.  Typed accessors with defaults; unknown options are
//! an error so typos fail loudly.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token (the subcommand), if any.
    pub command: Option<String>,
    /// `--key value` / `--key=value` options.
    opts: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    flags: Vec<String>,
    /// Remaining positional arguments (after the subcommand).
    pub positional: Vec<String>,
    /// Option keys that were consumed via accessors (for strict checking).
    seen: std::cell::RefCell<Vec<String>>,
}

#[derive(thiserror::Error, Debug)]
pub enum CliError {
    #[error("option --{0} expects a value")]
    MissingValue(String),
    #[error("invalid value for --{key}: {value} ({hint})")]
    BadValue { key: String, value: String, hint: String },
    #[error("unknown option(s): {0}")]
    Unknown(String),
}

/// `--resume`: the checkpoint exists and is readable, but was produced
/// by a different run configuration (fix the config, not the disk).
pub const EXIT_RESUME_MISMATCH: i32 = 3;
/// `--resume`: the selected checkpoint bytes are corrupt or unreadable
/// and no older candidate survived (fix the disk).
pub const EXIT_RESUME_CORRUPT: i32 = 4;
/// `--resume`: nothing restorable at the target — missing file, empty
/// directory, or every candidate is ledger-unverified (nothing to fix;
/// start fresh).
pub const EXIT_RESUME_NONE: i32 = 5;
/// `--rendezvous`: the rendezvous file belongs to a different run or an
/// older generation of this one — a stale artifact that would wire this
/// process into the wrong world (delete the file, or point the launch
/// at a fresh path).
pub const EXIT_STALE_RENDEZVOUS: i32 = 6;

/// An error carrying a specific process exit code.  `cli_main`
/// downcasts the `anyhow` chain for one of these and exits with
/// `code`; any other error exits 1.  Codes 0/1/2 keep their historical
/// meanings (ok / generic error / usage), so the resume-failure
/// taxonomy starts at [`EXIT_RESUME_MISMATCH`] — supervisors and
/// scripts can tell "fix the config" from "fix the disk" from "nothing
/// to resume" without parsing stderr.
#[derive(thiserror::Error, Debug)]
#[error("{msg}")]
pub struct CliExit {
    pub code: i32,
    pub msg: String,
}

impl CliExit {
    /// Build an `anyhow::Error` that exits with `code`.
    pub fn err(code: i32, msg: impl Into<String>) -> anyhow::Error {
        anyhow::Error::new(CliExit { code, msg: msg.into() })
    }
}

impl Args {
    /// Parse from an explicit token list (tests) — `argv[0]` excluded.
    pub fn parse_from<I, S>(tokens: I) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut out = Args::default();
        let toks: Vec<String> = tokens.into_iter().map(Into::into).collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some(eq) = body.find('=') {
                    out.opts.insert(body[..eq].to_string(),
                                    body[eq + 1..].to_string());
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    out.opts.insert(body.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(t.clone());
            } else {
                out.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// Parse from the process environment.
    pub fn parse() -> Result<Args, CliError> {
        Self::parse_from(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.seen.borrow_mut().push(key.to_string());
    }

    /// String option with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.opts.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.opts.get(key).cloned()
    }

    /// Optional option under any of several spellings (e.g. `--topo` /
    /// `--topology`).  All keys are consumed for strict checking; the
    /// first key present wins.
    pub fn get_opt_alias(&self, keys: &[&str]) -> Option<String> {
        let mut found = None;
        for key in keys {
            let v = self.get_opt(key);
            if found.is_none() {
                found = v;
            }
        }
        found
    }

    /// Typed option with default.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T)
        -> Result<T, CliError> {
        self.mark(key);
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.to_string(),
                value: v.clone(),
                hint: std::any::type_name::<T>().to_string(),
            }),
        }
    }

    /// Boolean switch: present as `--flag`, or `--flag true/false`.
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.opts.get(key).map(|s| s.as_str()),
                 Some("true") | Some("1") | Some("yes"))
    }

    /// Tri-state boolean: `None` when the option is absent, `Some(true)`
    /// for a bare `--key` or `--key true/1/yes`, `Some(false)` for any
    /// other explicit value — lets a CLI flag override a config default
    /// in either direction without clobbering it when unspecified.
    pub fn flag_opt(&self, key: &str) -> Option<bool> {
        self.mark(key);
        if self.flags.iter().any(|f| f == key) {
            return Some(true);
        }
        self.opts.get(key).map(|s| {
            matches!(s.as_str(), "true" | "1" | "yes")
        })
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        self.mark(key);
        match self.opts.get(key) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) if v.is_empty() => Vec::new(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    /// Optional comma-separated list option: `None` when absent (so the
    /// caller can tell "not given" from "given empty"), `Some(items)`
    /// otherwise.
    pub fn get_list_opt(&self, key: &str) -> Option<Vec<String>> {
        self.mark(key);
        self.opts.get(key).map(|v| {
            if v.is_empty() {
                Vec::new()
            } else {
                v.split(',').map(|s| s.trim().to_string()).collect()
            }
        })
    }

    /// After all accessors ran, error on any unconsumed option/flag.
    pub fn finish_strict(&self) -> Result<(), CliError> {
        let seen = self.seen.borrow();
        let mut unknown: Vec<String> = self
            .opts
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .map(|k| format!("--{k}"))
            .collect();
        unknown.dedup();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::Unknown(unknown.join(", ")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cli_exit_downcasts_through_an_anyhow_chain() {
        use anyhow::Context as _;
        let e = CliExit::err(EXIT_RESUME_CORRUPT, "ckpt unreadable")
            .context("cannot resume");
        assert_eq!(e.downcast_ref::<CliExit>().map(|x| x.code),
                   Some(EXIT_RESUME_CORRUPT));
        assert!(format!("{e:#}").contains("ckpt unreadable"));
        let plain = anyhow::anyhow!("some other failure");
        assert!(plain.downcast_ref::<CliExit>().is_none());
    }

    #[test]
    fn parses_subcommand_options_flags_positionals() {
        let a = Args::parse_from(["train", "--steps", "100", "--fast",
                                  "--lr=0.1", "path1", "path2"]).unwrap();
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get_parse("steps", 0usize).unwrap(), 100);
        assert_eq!(a.get_parse("lr", 0.0f64).unwrap(), 0.1);
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["path1", "path2"]);
    }

    #[test]
    fn defaults_apply_when_missing() {
        let a = Args::parse_from(["x"]).unwrap();
        assert_eq!(a.get("name", "dflt"), "dflt");
        assert_eq!(a.get_parse("k", 7i32).unwrap(), 7);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn bad_typed_value_is_error() {
        let a = Args::parse_from(["x", "--n", "abc"]).unwrap();
        assert!(a.get_parse("n", 0u32).is_err());
    }

    #[test]
    fn strict_mode_catches_typos() {
        let a = Args::parse_from(["x", "--stps", "5"]).unwrap();
        let _ = a.get_parse("steps", 0usize);
        assert!(a.finish_strict().is_err());
    }

    #[test]
    fn alias_options_consume_all_spellings() {
        let a = Args::parse_from(["x", "--topology", "2M4G"]).unwrap();
        assert_eq!(a.get_opt_alias(&["topo", "topology"]).as_deref(),
                   Some("2M4G"));
        a.finish_strict().unwrap();
        // the first present spelling wins
        let b = Args::parse_from(["x", "--topo=1M2G", "--topology=8M8G"])
            .unwrap();
        assert_eq!(b.get_opt_alias(&["topo", "topology"]).as_deref(),
                   Some("1M2G"));
        b.finish_strict().unwrap();
        // absent everywhere -> None, still consumed
        let c = Args::parse_from(["x"]).unwrap();
        assert_eq!(c.get_opt_alias(&["topo", "topology"]), None);
        c.finish_strict().unwrap();
    }

    #[test]
    fn list_option() {
        let a = Args::parse_from(["x", "--v", "a, b,c"]).unwrap();
        assert_eq!(a.get_list("v", &[]), vec!["a", "b", "c"]);
        assert_eq!(a.get_list("w", &["d"]), vec!["d"]);
    }

    #[test]
    fn optional_list_distinguishes_absent_from_empty() {
        let a = Args::parse_from(["x", "--hosts", "h1:1, h2:2"]).unwrap();
        assert_eq!(a.get_list_opt("hosts"),
                   Some(vec!["h1:1".to_string(), "h2:2".to_string()]));
        assert_eq!(a.get_list_opt("peers"), None);
        a.finish_strict().unwrap();
        let b = Args::parse_from(["x", "--hosts="]).unwrap();
        assert_eq!(b.get_list_opt("hosts"), Some(Vec::new()));
    }

    #[test]
    fn boolean_with_explicit_value() {
        let a = Args::parse_from(["x", "--overlap", "true"]).unwrap();
        assert!(a.flag("overlap"));
        let b = Args::parse_from(["x", "--overlap=false"]).unwrap();
        assert!(!b.flag("overlap"));
    }

    #[test]
    fn tri_state_flag_distinguishes_absent_from_false() {
        let a = Args::parse_from(["x"]).unwrap();
        assert_eq!(a.flag_opt("overlap"), None);
        let b = Args::parse_from(["x", "--overlap"]).unwrap();
        assert_eq!(b.flag_opt("overlap"), Some(true));
        let c = Args::parse_from(["x", "--overlap=false"]).unwrap();
        assert_eq!(c.flag_opt("overlap"), Some(false));
        let d = Args::parse_from(["x", "--overlap", "true"]).unwrap();
        assert_eq!(d.flag_opt("overlap"), Some(true));
        // consumed keys pass strict checking
        c.finish_strict().unwrap();
    }
}
