//! Fine-tuning (paper §3.1.2, §5.3): extractive-QA span prediction on a
//! SQuAD-mechanism task.
//!
//! The real SQuAD v1.1 is not shippable offline, so the substitution
//! (DESIGN.md §2) is a synthetic extractive task with the same
//! *mechanism*: `[CLS] question [SEP] context [SEP]`, labels = the
//! (start, end) token span of the answer inside the context, loss =
//! start/end cross-entropy on a span head over the encoder.  The
//! question is the answer span itself (a copy task), so a correctly
//! wired encoder+head learns it quickly — exactly the signal the §5.3
//! experiment needs: fine-tuning a pretrained checkpoint converges
//! faster / lower than a random-init one.

use std::path::Path;

use crate::checkpoint::{self, AsyncCheckpointWriter, Checkpoint};
use crate::data::special;
use crate::metrics::LossCurve;
use crate::runtime::{Engine, QaBatch, StepScratch};
use crate::util::Pcg64;

/// One synthetic QA example.
#[derive(Debug, Clone)]
pub struct QaExample {
    pub question: Vec<u32>,
    pub context: Vec<u32>,
    /// Answer span within the CONTEXT (inclusive start, inclusive end).
    pub answer: (usize, usize),
}

/// Generate a batch of synthetic extractive-QA examples.
pub fn gen_examples(rng: &mut Pcg64, n: usize, context_len: usize,
                    vocab_size: u32) -> Vec<QaExample> {
    (0..n)
        .map(|_| {
            let context: Vec<u32> = (0..context_len)
                .map(|_| {
                    special::FIRST_FREE
                        + rng.gen_range((vocab_size - special::FIRST_FREE)
                            as u64) as u32
                })
                .collect();
            let span_len = rng.range_usize(1, 4.min(context_len) + 1);
            let start = rng.range_usize(0, context_len - span_len + 1);
            let end = start + span_len - 1;
            QaExample {
                question: context[start..=end].to_vec(),
                context,
                answer: (start, end),
            }
        })
        .collect()
}

/// Assemble examples into the QA batch tensors:
/// `[CLS] question [SEP] context [SEP] PAD...`, with start/end labels
/// re-based to the assembled sequence.
pub fn build_qa_batch(examples: &[QaExample], seq: usize) -> QaBatch {
    let b = examples.len();
    let mut out = QaBatch::zeros(b, seq);
    for (row, ex) in examples.iter().enumerate() {
        let base = row * seq;
        let mut pos = 0usize;
        let mut put = |o: &mut QaBatch, id: u32, seg: i32, p: &mut usize| {
            if *p < seq {
                o.input_ids[base + *p] = id as i32;
                o.token_type_ids[base + *p] = seg;
                o.attention_mask[base + *p] = 1;
                *p += 1;
            }
        };
        put(&mut out, special::CLS, 0, &mut pos);
        for &t in &ex.question {
            put(&mut out, t, 0, &mut pos);
        }
        put(&mut out, special::SEP, 0, &mut pos);
        let ctx_base = pos;
        for &t in &ex.context {
            put(&mut out, t, 1, &mut pos);
        }
        put(&mut out, special::SEP, 1, &mut pos);
        let start = (ctx_base + ex.answer.0).min(seq - 1);
        let end = (ctx_base + ex.answer.1).min(seq - 1);
        out.start_positions[row] = start as i32;
        out.end_positions[row] = end as i32;
    }
    out
}

/// Fine-tuning outcome (the §5.3 artifact).
///
/// The curves (and `final_exact`, a tail mean over them) cover only
/// the steps THIS call executed: a resumed run reports the post-resume
/// span, so its curve metrics are not comparable to an uninterrupted
/// run's even though `final_params` is bitwise identical.
#[derive(Debug, Default)]
pub struct FinetuneReport {
    pub loss: LossCurve,
    pub exact_match: LossCurve,
    pub final_exact: f64,
    /// Fine-tuned parameters (encoder + QA head) after the last step —
    /// what the resume-exactness tests compare bitwise.
    pub final_params: Vec<f32>,
}

/// Checkpointing knobs for the fine-tune loop: the same v2 subsystem as
/// the trainer (async rotated saves off the hot loop, exact resume from
/// the newest rotation file).  Finetune snapshots carry a reduced
/// fingerprint — (batch, seq, lr, seed), the fields that shape the
/// synthetic example stream and update rule — validated on resume so a
/// mismatched continuation fails loudly; with it, the per-step keyed
/// example RNG makes a resumed run bitwise-identical to an
/// uninterrupted one.
pub struct FinetuneCkpt<'a> {
    /// Rotation directory (`ckpt-*.bckp` files).
    pub dir: &'a Path,
    /// Steps between periodic saves (0 = only resume, never save).
    pub save_every: usize,
    /// Keep the newest K rotation files.
    pub keep_last: usize,
    /// Resume from the newest rotation file in `dir` when one exists.
    pub resume: bool,
}

/// Extend a pretraining flat vector with a fresh QA head.
pub fn extend_with_head(pre_params: &[f32], n_ft: usize, rng: &mut Pcg64)
    -> Vec<f32> {
    let mut out = Vec::with_capacity(n_ft);
    out.extend_from_slice(pre_params);
    while out.len() < n_ft {
        out.push((rng.next_gaussian() * 0.02) as f32);
    }
    out
}

/// The reduced fingerprint a finetune snapshot is stamped with: the
/// knobs that shape the example stream and the update rule.  Unused
/// trainer-only fields are zeroed (there is no distributed stream to
/// pin here).
fn finetune_fingerprint(batch: usize, seq: usize, lr: f32, seed: u64)
    -> crate::checkpoint::Fingerprint {
    crate::checkpoint::Fingerprint {
        machines: 1,
        gpus_per_machine: 1,
        micro_batch: batch as u32,
        seq_len: seq as u32,
        accum_steps: 1,
        seed,
        lr: lr as f64,
        ..Default::default()
    }
}

/// The step-keyed example RNG: like the trainer's batch cursor, example
/// generation is a pure function of `(seed, step index)`, never of run
/// history — a resumed run regenerates exactly the batches the
/// uninterrupted one would have seen.
fn example_rng(seed: u64, step: usize) -> Pcg64 {
    Pcg64::with_stream(
        seed ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        0x0A17,
    )
}

/// Run QA fine-tuning for `steps` steps; `pre_params` is the pretrained
/// checkpoint (or a random init for the from-scratch baseline).
pub fn run_finetune(engine: &Engine, preset: &str, pre_params: &[f32],
                    steps: usize, batch: usize, seq: usize, lr: f32,
                    seed: u64) -> anyhow::Result<FinetuneReport> {
    run_finetune_ckpt(engine, preset, pre_params, steps, batch, seq, lr,
                      seed, None)
}

/// [`run_finetune`] with v2 checkpointing: periodic async rotated saves
/// and exact resume from the newest rotation file.
#[allow(clippy::too_many_arguments)]
pub fn run_finetune_ckpt(engine: &Engine, preset: &str, pre_params: &[f32],
                         steps: usize, batch: usize, seq: usize, lr: f32,
                         seed: u64, ckpt: Option<FinetuneCkpt<'_>>)
                         -> anyhow::Result<FinetuneReport> {
    let model = engine.model(preset)?;
    let n_ft = model.finetune_param_count;
    let step = engine.qa_step(preset, batch, seq)?;
    let apply = engine.qa_apply(preset)?;

    let mut rng = Pcg64::with_stream(seed, 0x0A);
    let mut params = extend_with_head(pre_params, n_ft, &mut rng);
    let mut m = vec![0.0f32; n_ft];
    let mut v = vec![0.0f32; n_ft];
    let mut start = 0usize;

    // Checkpointing: resume first (overrides the fresh init), then
    // stand up the background rotation writer.
    let save_every = ckpt.as_ref().map_or(0, |c| c.save_every);
    let stamp = finetune_fingerprint(batch, seq, lr, seed);
    let mut writer = None;
    if let Some(ck) = &ckpt {
        if ck.resume {
            if let Some(path) = checkpoint::latest_checkpoint(ck.dir)? {
                let c = Checkpoint::load(&path)?;
                anyhow::ensure!(
                    c.params.len() == n_ft,
                    "finetune checkpoint {} holds {} params, model wants {}",
                    path.display(), c.params.len(), n_ft
                );
                // a snapshot from a different (batch, seq, lr, seed)
                // run would silently diverge from both streams
                c.ensure_fingerprint(&stamp)?;
                anyhow::ensure!(
                    (c.step as usize) < steps,
                    "finetune checkpoint {} is already at step {} — \
                     nothing left of the requested {} steps; raise \
                     `steps` or start without resume",
                    path.display(), c.step, steps
                );
                log::info!("finetune resume {}: step {}", path.display(),
                           c.step);
                start = c.step as usize;
                params = c.params;
                m = c.m;
                v = c.v;
            }
        }
        if ck.save_every > 0 {
            writer = Some(AsyncCheckpointWriter::new(ck.dir, ck.keep_last)?);
        }
    }

    let mut report = FinetuneReport::default();
    let context_len = (seq - 8).min(16);

    // Zero-copy hot path: one marshaling scratch + one gradient buffer
    // for the whole run (params mutate in place each step, so the step
    // counter versions the cached literal).
    let mut scratch = StepScratch::new();
    let mut grads = vec![0.0f32; n_ft];
    for s in start..steps {
        let mut ex_rng = example_rng(seed, s);
        let exs = gen_examples(&mut ex_rng, batch, context_len,
                               model.config.vocab_size as u32);
        let qb = build_qa_batch(&exs, seq);
        let out = step.run_scratch(&mut scratch, &params, s as u64, &qb,
                                   1.0, &mut grads)?;
        report.loss.push(s, out.loss as f64);
        report.exact_match.push(s, out.exact as f64);
        apply.run(&mut params, &grads, &mut m, &mut v, (s + 1) as f32,
                  lr)?;
        if let Some(w) = writer.as_mut() {
            if (s + 1) % save_every == 0 {
                w.save(|c| {
                    c.step = (s + 1) as u64;
                    c.data_step = (s + 1) as u64;
                    c.fingerprint = Some(stamp);
                    c.exact_data_position = true;
                    c.fill_arrays(&params, &m, &v);
                })?;
            }
        }
    }
    if let Some(w) = writer {
        w.finish()?;
    }
    report.final_exact = report.exact_match.tail_mean(5);
    report.final_params = params;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_have_valid_spans() {
        let mut rng = Pcg64::new(1);
        for ex in gen_examples(&mut rng, 50, 12, 512) {
            let (s, e) = ex.answer;
            assert!(s <= e && e < ex.context.len());
            assert_eq!(ex.question, ex.context[s..=e].to_vec());
            assert!(ex.question.len() <= 4);
        }
    }

    #[test]
    fn batch_layout_and_labels() {
        let ex = QaExample {
            question: vec![100, 101],
            context: vec![200, 100, 101, 203],
            answer: (1, 2),
        };
        let b = build_qa_batch(&[ex], 16);
        // [CLS] 100 101 [SEP] 200 100 101 203 [SEP]
        assert_eq!(b.input_ids[0], special::CLS as i32);
        assert_eq!(b.input_ids[3], special::SEP as i32);
        assert_eq!(b.input_ids[4], 200);
        assert_eq!(b.input_ids[8], special::SEP as i32);
        // answer tokens are at assembled positions 5..=6
        assert_eq!(b.start_positions[0], 5);
        assert_eq!(b.end_positions[0], 6);
        assert_eq!(b.input_ids[5], 100);
        assert_eq!(b.input_ids[6], 101);
        // padding after SEP
        assert_eq!(b.attention_mask[9], 0);
    }

    #[test]
    fn head_extension_preserves_prefix() {
        let pre = vec![1.0f32, 2.0, 3.0];
        let mut rng = Pcg64::new(2);
        let ft = extend_with_head(&pre, 8, &mut rng);
        assert_eq!(&ft[..3], &[1.0, 2.0, 3.0]);
        assert_eq!(ft.len(), 8);
        assert!(ft[3..].iter().any(|&x| x != 0.0));
    }
}
