//! Terminal line/bar plots for the bench harnesses — the figures of the
//! paper (loss curves, scaling curves, timelines) are rendered as ASCII
//! so `cargo bench` output is self-contained and diffable.

/// A named data series for [`plot_series`].
pub struct Series<'a> {
    pub name: &'a str,
    pub points: &'a [(f64, f64)],
    pub marker: char,
}

/// Render one or more (x, y) series on a shared-axis ASCII grid.
pub fn plot_series(title: &str, series: &[Series], width: usize,
                   height: usize) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().cloned())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if all.is_empty() {
        out.push_str("(no data)\n");
        return out;
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for (x, y) in &all {
        xmin = xmin.min(*x);
        xmax = xmax.max(*x);
        ymin = ymin.min(*y);
        ymax = ymax.max(*y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for (x, y) in s.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64)
                .round() as usize;
            let cy = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64)
                .round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = s.marker;
        }
    }
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{ymax:>10.3} |")
        } else if i == height - 1 {
            format!("{ymin:>10.3} |")
        } else {
            format!("{:>10} |", "")
        };
        out.push_str(&label);
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:>10}  {}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>10}  {:<.3}{}{:>.3}\n", "", xmin,
                          " ".repeat(width.saturating_sub(12)), xmax));
    for s in series {
        out.push_str(&format!("    {} = {}\n", s.marker, s.name));
    }
    out
}

/// Horizontal bar chart: one labelled bar per (label, value).
pub fn bar_chart(title: &str, rows: &[(String, f64)], width: usize) -> String {
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    let max = rows.iter().map(|(_, v)| *v).fold(0.0f64, f64::max);
    let lw = rows.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    for (label, v) in rows {
        let n = if max > 0.0 {
            ((v / max) * width as f64).round() as usize
        } else {
            0
        };
        out.push_str(&format!("{:<lw$}  {} {:.3}\n", label,
                              "#".repeat(n.max(if *v > 0.0 {1} else {0})), v));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plot_contains_markers_and_legend() {
        let pts = [(0.0, 0.0), (1.0, 1.0), (2.0, 4.0)];
        let s = Series { name: "sq", points: &pts, marker: '*' };
        let out = plot_series("t", &[s], 40, 10);
        assert!(out.contains('*'));
        assert!(out.contains("sq"));
    }

    #[test]
    fn plot_empty_is_graceful() {
        let s = Series { name: "e", points: &[], marker: 'x' };
        assert!(plot_series("t", &[s], 10, 5).contains("no data"));
    }

    #[test]
    fn bars_scale_with_value() {
        let rows = vec![("a".to_string(), 1.0), ("b".to_string(), 2.0)];
        let out = bar_chart("t", &rows, 20);
        let a_hashes = out.lines().nth(1).unwrap().matches('#').count();
        let b_hashes = out.lines().nth(2).unwrap().matches('#').count();
        assert!(b_hashes > a_hashes);
    }
}
