//! BLAKE2s (RFC 7693) with keyed-MAC support, hand-rolled because the
//! offline build has no crypto crates (substrate per DESIGN.md §10).
//!
//! The transport layer uses the keyed mode to authenticate socket
//! handshakes (`--net-key`): a 16-byte MAC over the handshake fields
//! plus a per-run nonce rejects stale or foreign processes before they
//! can join an exchange.  Only the sequential single-shot path is
//! implemented — handshakes are tiny, so there is no streaming state.
//!
//! Correctness is pinned by golden vectors generated with an
//! independent implementation (CPython's `hashlib.blake2s`).

/// Initialization vector (RFC 7693 §2.6): the SHA-256 IV words.
const IV: [u32; 8] = [
    0x6a09_e667,
    0xbb67_ae85,
    0x3c6e_f372,
    0xa54f_f53a,
    0x510e_527f,
    0x9b05_688c,
    0x1f83_d9ab,
    0x5be0_cd19,
];

/// Message-word permutation schedule (RFC 7693 §2.7).  BLAKE2s runs
/// 10 rounds; row `r` gives the word order for round `r`.
const SIGMA: [[usize; 16]; 10] = [
    [0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15],
    [14, 10, 4, 8, 9, 15, 13, 6, 1, 12, 0, 2, 11, 7, 5, 3],
    [11, 8, 12, 0, 5, 2, 15, 13, 10, 14, 3, 6, 7, 1, 9, 4],
    [7, 9, 3, 1, 13, 12, 11, 14, 2, 6, 5, 10, 4, 0, 15, 8],
    [9, 0, 5, 7, 2, 4, 10, 15, 14, 1, 11, 12, 6, 8, 3, 13],
    [2, 12, 6, 10, 0, 11, 8, 3, 4, 13, 7, 5, 15, 14, 1, 9],
    [12, 5, 1, 15, 14, 13, 4, 10, 0, 7, 6, 3, 9, 2, 8, 11],
    [13, 11, 7, 14, 12, 1, 3, 9, 5, 0, 15, 4, 8, 6, 2, 10],
    [6, 15, 14, 9, 11, 3, 0, 8, 12, 2, 13, 7, 1, 4, 10, 5],
    [10, 2, 8, 4, 7, 6, 1, 5, 15, 11, 9, 14, 3, 12, 13, 0],
];

/// The G mixing function (RFC 7693 §3.1), BLAKE2s rotation constants.
#[inline]
fn g(v: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize, x: u32, y: u32) {
    v[a] = v[a].wrapping_add(v[b]).wrapping_add(x);
    v[d] = (v[d] ^ v[a]).rotate_right(16);
    v[c] = v[c].wrapping_add(v[d]);
    v[b] = (v[b] ^ v[c]).rotate_right(12);
    v[a] = v[a].wrapping_add(v[b]).wrapping_add(y);
    v[d] = (v[d] ^ v[a]).rotate_right(8);
    v[c] = v[c].wrapping_add(v[d]);
    v[b] = (v[b] ^ v[c]).rotate_right(7);
}

/// Compress one 64-byte block into the state.  `t` is the total byte
/// count absorbed so far (including this block), `last` marks the final
/// block of the input.
fn compress(h: &mut [u32; 8], block: &[u8; 64], t: u64, last: bool) {
    let mut m = [0u32; 16];
    for (i, w) in m.iter_mut().enumerate() {
        *w = u32::from_le_bytes(block[4 * i..4 * i + 4].try_into().unwrap());
    }
    let mut v = [0u32; 16];
    v[..8].copy_from_slice(h);
    v[8..].copy_from_slice(&IV);
    v[12] ^= t as u32;
    v[13] ^= (t >> 32) as u32;
    if last {
        v[14] ^= 0xffff_ffff;
    }
    for s in &SIGMA {
        g(&mut v, 0, 4, 8, 12, m[s[0]], m[s[1]]);
        g(&mut v, 1, 5, 9, 13, m[s[2]], m[s[3]]);
        g(&mut v, 2, 6, 10, 14, m[s[4]], m[s[5]]);
        g(&mut v, 3, 7, 11, 15, m[s[6]], m[s[7]]);
        g(&mut v, 0, 5, 10, 15, m[s[8]], m[s[9]]);
        g(&mut v, 1, 6, 11, 12, m[s[10]], m[s[11]]);
        g(&mut v, 2, 7, 8, 13, m[s[12]], m[s[13]]);
        g(&mut v, 3, 4, 9, 14, m[s[14]], m[s[15]]);
    }
    for i in 0..8 {
        h[i] ^= v[i] ^ v[i + 8];
    }
}

/// Single-shot BLAKE2s.  `out_len` is the digest length in bytes
/// (1..=32); an empty `key` selects the plain hash, a non-empty key
/// (at most 32 bytes) selects the keyed MAC mode.
///
/// Panics on out-of-range `out_len` or an over-long key — both are
/// compile-time choices at every call site, never runtime input.
pub fn blake2s(out_len: usize, key: &[u8], msg: &[u8]) -> Vec<u8> {
    assert!(
        (1..=32).contains(&out_len),
        "blake2s digest length {out_len} not in 1..=32"
    );
    assert!(key.len() <= 32, "blake2s key longer than 32 bytes");

    let mut h = IV;
    h[0] ^= 0x0101_0000 ^ ((key.len() as u32) << 8) ^ out_len as u32;
    let mut t: u64 = 0;

    if !key.is_empty() {
        // Keyed mode prepends the zero-padded key as a full first block.
        let mut block = [0u8; 64];
        block[..key.len()].copy_from_slice(key);
        t += 64;
        if msg.is_empty() {
            compress(&mut h, &block, t, true);
            return digest(&h, out_len);
        }
        compress(&mut h, &block, t, false);
    }

    if msg.is_empty() {
        // Unkeyed empty input: one all-zero final block at t = 0.
        compress(&mut h, &[0u8; 64], 0, true);
        return digest(&h, out_len);
    }

    let mut chunks = msg.chunks(64).peekable();
    while let Some(c) = chunks.next() {
        let mut block = [0u8; 64];
        block[..c.len()].copy_from_slice(c);
        t += c.len() as u64;
        compress(&mut h, &block, t, chunks.peek().is_none());
    }
    digest(&h, out_len)
}

fn digest(h: &[u32; 8], out_len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(out_len);
    for w in h {
        out.extend_from_slice(&w.to_le_bytes());
    }
    out.truncate(out_len);
    out
}

/// 16-byte keyed MAC — the handshake-authentication shape.
pub fn mac16(key: &[u8], msg: &[u8]) -> [u8; 16] {
    blake2s(16, key, msg).try_into().unwrap()
}

/// 8-byte keyed digest — run fingerprints and per-epoch nonces.
pub fn mac8(key: &[u8], msg: &[u8]) -> [u8; 8] {
    blake2s(8, key, msg).try_into().unwrap()
}

/// Constant-time equality for MAC comparison: never short-circuits on
/// the first differing byte.
pub fn ct_eq(a: &[u8], b: &[u8]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut acc = 0u8;
    for (x, y) in a.iter().zip(b) {
        acc |= x ^ y;
    }
    acc == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    // Golden vectors generated with CPython: hashlib.blake2s(msg,
    // digest_size=n, key=k).hexdigest().

    #[test]
    fn unkeyed_golden_vectors() {
        assert_eq!(
            hex(&blake2s(32, b"", b"")),
            "69217a3079908094e11121d042354a7c1f55b6482ca1a51e1b250dfd1ed0eef9"
        );
        assert_eq!(
            hex(&blake2s(32, b"", b"abc")),
            "508c5e8c327c14e2e1a72ba34eeb452f37458b209ed63a294d999b4c86675982"
        );
    }

    #[test]
    fn keyed_mac16_golden_vectors() {
        assert_eq!(
            hex(&mac16(b"secret", b"hello")),
            "2f259d17665eaf770e406b485cc47132"
        );
        let key: Vec<u8> = (0u8..=31).collect();
        let msg: Vec<u8> = (0u8..=99).collect();
        assert_eq!(hex(&mac16(&key, &msg)), "0b67d33f8b859c3157fbabd9e6e47ed0");
        // Multi-block message (200 bytes > three 64-byte blocks).
        let long = vec![b'a'; 200];
        assert_eq!(
            hex(&mac16(b"net-key", &long)),
            "121a68c2c804d73ccd25c32388d1a64f"
        );
        // Keyed + empty message: the key block is the final block.
        assert_eq!(hex(&mac16(b"x", b"")), "800238da92946d454ca5f7e878a6a907");
    }

    #[test]
    fn keyed_full_width_golden_vector() {
        assert_eq!(
            hex(&blake2s(32, b"k", b"The quick brown fox jumps over the lazy dog")),
            "e12d78ae15072ffa5b5c7464c8096a0ff57deab7489569d108c707b2f3756f5c"
        );
    }

    #[test]
    fn digest_length_is_part_of_the_parameter_block() {
        // A 16-byte digest is NOT a truncated 32-byte digest.
        let d16 = blake2s(16, b"", b"abc");
        let d32 = blake2s(32, b"", b"abc");
        assert_ne!(d16[..], d32[..16]);
    }

    #[test]
    fn key_changes_the_digest() {
        assert_ne!(mac16(b"a", b"msg"), mac16(b"b", b"msg"));
        assert_ne!(mac8(b"a", b"msg")[..], mac16(b"a", b"msg")[..8]);
    }

    #[test]
    fn ct_eq_matches_slice_equality() {
        assert!(ct_eq(b"abcd", b"abcd"));
        assert!(!ct_eq(b"abcd", b"abce"));
        assert!(!ct_eq(b"abc", b"abcd"));
        assert!(ct_eq(b"", b""));
    }
}
