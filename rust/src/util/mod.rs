//! Small shared utilities: deterministic PRNG, CRC32, BLAKE2s (keyed
//! MAC for handshake auth), formatting helpers, a stopwatch, and
//! terminal plotting for the benchmark harnesses.
//!
//! These exist because the offline build has no `rand`, `humantime`, or
//! plotting crates — they are substrates per DESIGN.md §10.

pub mod ascii_plot;
pub mod blake2s;
pub mod crc32;
pub mod fmt;
pub mod prng;
pub mod stopwatch;

pub use ascii_plot::{plot_series, Series};
pub use crc32::crc32;
pub use fmt::{human_bytes, human_count, human_duration};
pub use prng::Pcg64;
pub use stopwatch::Stopwatch;
