//! Human-readable formatting for bytes, counts, and durations — used by
//! the CLI, the metrics reporters, and every bench harness table.

/// `1536 -> "1.50 KiB"`, `1.36e9 -> "1.27 GiB"`.
pub fn human_bytes(bytes: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes;
    let mut u = 0;
    while v.abs() >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", v as i64, UNITS[u])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// `16752700000 -> "16.75 G"` (SI, for token counts etc.).
pub fn human_count(n: f64) -> String {
    const UNITS: [&str; 5] = ["", "K", "M", "G", "T"];
    let mut v = n;
    let mut u = 0;
    while v.abs() >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{:.0}", v)
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Seconds to a compact human duration: `93784.0 -> "1d 2h 3m"`,
/// `0.00123 -> "1.23 ms"`.
pub fn human_duration(seconds: f64) -> String {
    if seconds < 0.0 {
        return format!("-{}", human_duration(-seconds));
    }
    if seconds < 1e-3 {
        return format!("{:.2} us", seconds * 1e6);
    }
    if seconds < 1.0 {
        return format!("{:.2} ms", seconds * 1e3);
    }
    if seconds < 60.0 {
        return format!("{:.2} s", seconds);
    }
    let total = seconds.round() as u64;
    let (d, rem) = (total / 86_400, total % 86_400);
    let (h, rem) = (rem / 3_600, rem % 3_600);
    let (m, s) = (rem / 60, rem % 60);
    let mut parts = Vec::new();
    if d > 0 {
        parts.push(format!("{d}d"));
    }
    if h > 0 {
        parts.push(format!("{h}h"));
    }
    if m > 0 && d == 0 {
        parts.push(format!("{m}m"));
    }
    if s > 0 && d == 0 && h == 0 {
        parts.push(format!("{s}s"));
    }
    if parts.is_empty() {
        parts.push("0s".to_string());
    }
    parts.join(" ")
}

/// Right-pad to `w` columns (for plain-text tables).
pub fn pad(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{s}{}", " ".repeat(w - s.len()))
    }
}

/// Left-pad to `w` columns.
pub fn rpad(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{}{s}", " ".repeat(w - s.len()))
    }
}

/// Render rows as an aligned table with a header separator.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let line: Vec<String> = headers
        .iter()
        .zip(&widths)
        .map(|(h, w)| pad(h, *w))
        .collect();
    out.push_str(&line.join("  "));
    out.push('\n');
    out.push_str(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>()
        .join("  "));
    out.push('\n');
    for row in rows {
        let line: Vec<String> = row
            .iter()
            .zip(&widths)
            .map(|(c, w)| pad(c, *w))
            .collect();
        out.push_str(&line.join("  "));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(human_bytes(512.0), "512 B");
        assert_eq!(human_bytes(1536.0), "1.50 KiB");
        assert_eq!(human_bytes(1.36e9), "1.27 GiB");
    }

    #[test]
    fn count_units() {
        assert_eq!(human_count(999.0), "999");
        assert_eq!(human_count(16_752_700_000.0), "16.75 G");
    }

    #[test]
    fn durations() {
        assert_eq!(human_duration(0.00123), "1.23 ms");
        assert_eq!(human_duration(45.0), "45.00 s");
        assert_eq!(human_duration(93_784.0), "1d 2h");
        assert_eq!(human_duration(3_660.0), "1h 1m");
    }

    #[test]
    fn table_alignment() {
        let t = render_table(&["a", "bb"], &[vec!["xxx".into(), "y".into()]]);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("a  "));
        assert!(lines[2].starts_with("xxx"));
    }
}
