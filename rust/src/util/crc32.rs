//! CRC-32 (IEEE 802.3 polynomial) — integrity checksum for `bshard` files.
//!
//! Table-driven, byte-at-a-time; the shard reader verifies every record's
//! CRC so silent corruption in the data pipeline is caught at load time
//! (the paper's hdf5 substrate gives the same guarantee via its checksums).

const POLY: u32 = 0xEDB8_8320;

fn table() -> &'static [u32; 256] {
    use once_cell::sync::OnceCell;
    static TABLE: OnceCell<[u32; 256]> = OnceCell::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 of `data` (IEEE, init 0xFFFFFFFF, final xor 0xFFFFFFFF).
pub fn crc32(data: &[u8]) -> u32 {
    let t = table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Incremental CRC-32 hasher for streaming writers.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Self { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let t = table();
        for &b in data {
            self.state = t[((self.state ^ b as u32) & 0xFF) as usize]
                ^ (self.state >> 8);
        }
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard test vectors for CRC-32/IEEE.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
                   0x414F_A339);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data = b"hello bertdist world";
        let mut h = Crc32::new();
        h.update(&data[..5]);
        h.update(&data[5..]);
        assert_eq!(h.finalize(), crc32(data));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0xA5u8; 1024];
        let before = crc32(&data);
        data[512] ^= 0x01;
        assert_ne!(before, crc32(&data));
    }
}
