//! Wall-clock stopwatch with named laps — the timing primitive behind the
//! bench harnesses and the trainer's per-phase breakdown (fwd / bwd /
//! allreduce / apply), mirroring the paper's Figure-2/5 span taxonomy.

use std::time::Instant;

/// A stopwatch that records named laps.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    laps: Vec<(String, f64)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, last: now, laps: Vec::new() }
    }

    /// Seconds since construction.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Record a lap: seconds since the previous lap (or start).
    pub fn lap(&mut self, name: &str) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.laps.push((name.to_string(), dt));
        dt
    }

    /// All recorded laps.
    pub fn laps(&self) -> &[(String, f64)] {
        &self.laps
    }

    /// Sum of laps with the given name.
    pub fn total(&self, name: &str) -> f64 {
        self.laps.iter().filter(|(n, _)| n == name).map(|(_, d)| d).sum()
    }

    /// Reset everything.
    pub fn reset(&mut self) {
        let now = Instant::now();
        self.start = now;
        self.last = now;
        self.laps.clear();
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` `n` times and return (min, mean, max) seconds — the bench
/// harness kernel (criterion is unavailable offline).
pub fn bench_times(n: usize, mut f: impl FnMut()) -> (f64, f64, f64) {
    assert!(n > 0);
    let mut times = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0, f64::max);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    (min, mean, max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        sw.lap("a");
        std::thread::sleep(std::time::Duration::from_millis(2));
        sw.lap("a");
        sw.lap("b");
        assert_eq!(sw.laps().len(), 3);
        assert!(sw.total("a") >= 0.004);
        assert!(sw.total("b") < sw.total("a"));
    }

    #[test]
    fn timed_returns_result() {
        let (v, dt) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn bench_times_ordering() {
        let (min, mean, max) = bench_times(5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(min <= mean && mean <= max);
    }
}
