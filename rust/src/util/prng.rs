//! PCG-XSL-RR 128/64 pseudo-random generator (O'Neill 2014).
//!
//! Deterministic, seedable, and fast — used everywhere randomness is
//! needed (corpus synthesis, masking, shuffling, property tests) so every
//! run of the system is exactly reproducible from its seed.  Substrate:
//! the `rand` crate is unavailable offline.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Create a generator with an explicit stream id; distinct streams
    /// from the same seed are independent (used per-worker).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Next u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire's method).
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Zipf-distributed rank in `[0, n)` with exponent `s` (corpus synth).
    /// Uses rejection-inversion (Hörmann & Derflinger).
    pub fn next_zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n >= 1);
        // Simple inverse-CDF on a cached-free harmonic approximation is
        // fine for corpus synthesis; exactness is not required.
        let hn = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                x.ln()
            } else {
                (x.powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let total = hn(n as f64 + 0.5) - hn(0.5);
        let u = self.next_f64() * total + hn(0.5);
        let x = if (s - 1.0).abs() < 1e-9 {
            u.exp()
        } else {
            (u * (1.0 - s) + 1.0).powf(1.0 / (1.0 - s))
        };
        let r = x.round() as i64 - 1;
        r.clamp(0, n as i64 - 1) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a: Vec<u64> = (0..16).map({
            let mut r = Pcg64::new(42);
            move |_| r.next_u64()
        }).collect();
        let b: Vec<u64> = (0..16).map({
            let mut r = Pcg64::new(42);
            move |_| r.next_u64()
        }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::with_stream(7, 0);
        let mut b = Pcg64::with_stream(7, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_stays_in_bounds() {
        let mut r = Pcg64::new(3);
        for bound in [1u64, 2, 7, 1000, u64::MAX / 2] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = Pcg64::new(9);
        let xs: Vec<f64> = (0..2000).map(|_| r.next_f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::new(11);
        let xs: Vec<f64> = (0..5000).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.1, "mean={mean}");
        assert!((var - 1.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>()); // overwhelmingly likely
    }

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let mut r = Pcg64::new(13);
        let mut counts = [0usize; 10];
        for _ in 0..20000 {
            let z = r.next_zipf(10, 1.2);
            counts[z] += 1;
        }
        assert!(counts[0] > counts[4], "{counts:?}");
        assert!(counts[0] > counts[9] * 3, "{counts:?}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::new(17);
        let s = r.sample_indices(50, 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }
}
