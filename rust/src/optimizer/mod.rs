//! Host-side optimizers (paper §2.1: LAMB for large-batch BERT; Adam as
//! the baseline it replaces).
//!
//! The hot training path applies updates through the AOT `apply_lamb`
//! HLO (fused Pallas kernels); these Rust implementations serve (a) the
//! pure-rust simulator mode, (b) golden cross-checks against the HLO in
//! the integration tests, and (c) the learning-rate schedule.

use crate::model::layout::ParamLayout;

/// LAMB/AdamW hyper-parameters (NVIDIA BERT recipe defaults).
#[derive(Debug, Clone, Copy)]
pub struct OptHyper {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub clip_norm: f32,
}

impl Default for OptHyper {
    fn default() -> Self {
        Self {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-6,
            weight_decay: 0.01,
            clip_norm: 1.0,
        }
    }
}

/// Optimizer state over the flat vector.
#[derive(Debug)]
pub struct OptState {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: usize,
}

impl OptState {
    pub fn new(n: usize) -> Self {
        Self { m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

/// Global-norm clip in place; returns the pre-clip norm.
pub fn clip_by_global_norm(grads: &mut [f32], clip: f32) -> f32 {
    let norm = l2_norm(grads);
    if norm > clip && norm > 0.0 {
        let s = clip / norm;
        for g in grads.iter_mut() {
            *g *= s;
        }
    }
    norm
}

/// L2 norm of a slice.
pub fn l2_norm(xs: &[f32]) -> f32 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
}

/// One LAMB step over the flat vector with PER-TENSOR trust ratios
/// (the layout supplies tensor boundaries — LAMB's "layer-wise" unit).
/// Matches `python/compile/kernels/fused_lamb.py` semantics.
pub fn lamb_step(params: &mut [f32], grads: &mut [f32], state: &mut OptState,
                 layout: &ParamLayout, lr: f32, h: &OptHyper) {
    state.step += 1;
    clip_by_global_norm(grads, h.clip_norm);
    let c1 = 1.0 - h.beta1.powi(state.step as i32);
    let c2 = 1.0 - h.beta2.powi(state.step as i32);
    // §Perf iteration 2: bias correction as multiply-by-inverse (the two
    // per-element divides were ~15% of the scalar pipeline).
    let ic1 = 1.0 / c1;
    let ic2 = 1.0 / c2;
    // One scratch buffer reused across tensors (perf: §Perf iteration 1 —
    // a fresh Vec per tensor cost ~8% of the step on bert-mini).
    let max_len = layout.entries().iter().map(|e| e.len()).max()
        .unwrap_or(0);
    let mut update = vec![0.0f32; max_len];
    for e in layout.entries() {
        let r = e.offset..e.offset + e.len();
        let (p, g) = (&mut params[r.clone()], &grads[r.clone()]);
        let (m, v) = (&mut state.m[r.clone()], &mut state.v[r]);
        let mut w_sq = 0.0f64;
        let mut u_sq = 0.0f64;
        // one fused pass: moments + update direction + norms
        for i in 0..p.len() {
            m[i] = h.beta1 * m[i] + (1.0 - h.beta1) * g[i];
            v[i] = h.beta2 * v[i] + (1.0 - h.beta2) * g[i] * g[i];
            let m_hat = m[i] * ic1;
            let v_hat = v[i] * ic2;
            let u = m_hat / (v_hat.sqrt() + h.eps) + h.weight_decay * p[i];
            update[i] = u;
            w_sq += (p[i] as f64) * (p[i] as f64);
            u_sq += (u as f64) * (u as f64);
        }
        let w_norm = w_sq.sqrt();
        let u_norm = u_sq.sqrt();
        let trust = if w_norm > 0.0 && u_norm > 0.0 {
            (w_norm / u_norm) as f32
        } else {
            1.0
        };
        for i in 0..p.len() {
            p[i] -= lr * trust * update[i];
        }
    }
}

/// One AdamW step over the flat vector.
pub fn adam_step(params: &mut [f32], grads: &mut [f32], state: &mut OptState,
                 lr: f32, h: &OptHyper) {
    state.step += 1;
    clip_by_global_norm(grads, h.clip_norm);
    let c1 = 1.0 - h.beta1.powi(state.step as i32);
    let c2 = 1.0 - h.beta2.powi(state.step as i32);
    for i in 0..params.len() {
        let g = grads[i];
        state.m[i] = h.beta1 * state.m[i] + (1.0 - h.beta1) * g;
        state.v[i] = h.beta2 * state.v[i] + (1.0 - h.beta2) * g * g;
        let m_hat = state.m[i] / c1;
        let v_hat = state.v[i] / c2;
        params[i] -=
            lr * (m_hat / (v_hat.sqrt() + h.eps)
                  + h.weight_decay * params[i]);
    }
}

/// Learning-rate schedule: linear warmup then inverse-sqrt-free linear
/// decay to zero at `total_steps` (the NVIDIA BERT pretraining schedule).
pub fn lr_schedule(base_lr: f64, step: usize, warmup: usize,
                   total_steps: usize) -> f64 {
    let s = step as f64;
    if step < warmup {
        return base_lr * s / warmup.max(1) as f64;
    }
    if total_steps <= warmup {
        return base_lr;
    }
    let progress = (s - warmup as f64)
        / (total_steps - warmup).max(1) as f64;
    base_lr * (1.0 - progress).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::layout::ParamLayout;
    use crate::testkit;
    use crate::util::Pcg64;

    fn layout2() -> ParamLayout {
        ParamLayout::from_shapes(&[
            ("a".into(), vec![8]),
            ("b".into(), vec![4, 4]),
        ])
    }

    #[test]
    fn clip_leaves_small_grads_alone() {
        let mut g = vec![0.1, -0.2, 0.05];
        let norm = clip_by_global_norm(&mut g, 1.0);
        assert!(norm < 1.0);
        assert_eq!(g, vec![0.1, -0.2, 0.05]);
    }

    #[test]
    fn clip_rescales_large_grads() {
        let mut g = vec![3.0, 4.0]; // norm 5
        clip_by_global_norm(&mut g, 1.0);
        let n = l2_norm(&g);
        assert!((n - 1.0).abs() < 1e-6);
        assert!((g[0] / g[1] - 0.75).abs() < 1e-6); // direction preserved
    }

    #[test]
    fn lamb_moves_params_and_adapts_per_tensor() {
        let layout = layout2();
        let mut p: Vec<f32> = (0..24).map(|i| 0.1 * (i as f32 + 1.0)).collect();
        let before = p.clone();
        let mut g = vec![0.01f32; 24];
        let mut st = OptState::new(24);
        lamb_step(&mut p, &mut g, &mut st, &layout, 0.01, &OptHyper::default());
        assert_ne!(p, before);
        assert!(p.iter().all(|x| x.is_finite()));
        assert_eq!(st.step, 1);
    }

    #[test]
    fn lamb_trust_ratio_scales_with_weight_norm() {
        // Same grads, 2x weights => larger absolute step (LAMB property).
        let layout = ParamLayout::from_shapes(&[("w".into(), vec![16])]);
        let h = OptHyper::default();
        let run = |scale: f32| {
            let mut p = vec![scale; 16];
            let before = p.clone();
            let mut g = vec![0.5f32; 16];
            let mut st = OptState::new(16);
            lamb_step(&mut p, &mut g, &mut st, &layout, 0.01, &h);
            p.iter().zip(&before).map(|(a, b)| (a - b).abs()).sum::<f32>()
        };
        assert!(run(2.0) > 1.5 * run(1.0));
    }

    #[test]
    fn adam_matches_closed_form_first_step() {
        // With m=v=0, first Adam step is -lr * g/(|g| + eps') - lr*wd*p
        // after bias correction cancels.
        let h = OptHyper { weight_decay: 0.0, clip_norm: 1e9,
                           ..Default::default() };
        let mut p = vec![1.0f32];
        let mut g = vec![0.5f32];
        let mut st = OptState::new(1);
        adam_step(&mut p, &mut g, &mut st, 0.1, &h);
        // m_hat = g, v_hat = g^2 -> update = g/|g| = 1 -> p = 1 - 0.1
        assert!((p[0] - 0.9).abs() < 1e-3, "{}", p[0]);
    }

    #[test]
    fn lr_schedule_shape() {
        let base = 1e-4;
        assert_eq!(lr_schedule(base, 0, 10, 100), 0.0);
        assert!((lr_schedule(base, 5, 10, 100) - base * 0.5).abs() < 1e-12);
        assert!((lr_schedule(base, 10, 10, 100) - base).abs() < 1e-12);
        assert!(lr_schedule(base, 55, 10, 100) < base);
        assert_eq!(lr_schedule(base, 100, 10, 100), 0.0);
        // never negative
        assert_eq!(lr_schedule(base, 1000, 10, 100), 0.0);
    }

    #[test]
    fn prop_optimizers_keep_params_finite() {
        testkit::check(
            "opt-finite", 0x0F7, 24,
            |r: &mut Pcg64| {
                let p = testkit::gen_f32_vec(r, 24, 24);
                let g = testkit::gen_f32_vec(r, 24, 24);
                (p, g, r.chance(0.5))
            },
            |(p0, g0, use_lamb)| {
                let layout = layout2();
                let mut p = p0.clone();
                let mut st = OptState::new(24);
                let h = OptHyper::default();
                for step in 0..5 {
                    let mut g: Vec<f32> = g0.iter()
                        .map(|x| x * (step as f32 + 1.0) * 0.1)
                        .collect();
                    if *use_lamb {
                        lamb_step(&mut p, &mut g, &mut st, &layout, 0.01, &h);
                    } else {
                        adam_step(&mut p, &mut g, &mut st, 0.01, &h);
                    }
                }
                p.iter().all(|x| x.is_finite())
            },
        );
    }
}
