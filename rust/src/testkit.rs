//! Property-testing mini-framework (substrate: `proptest` is unavailable
//! offline — DESIGN.md §10).
//!
//! Provides seeded random-input property runners with first-failure
//! shrinking for integer-vector inputs.  Used by the coordinator modules
//! to check invariants (allreduce ≡ serial sum, shard round-trip, bucket
//! partition laws, tokenizer consistency, ...).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::RunConfig;
use crate::data::ShardedDataset;
use crate::runtime::Engine;
use crate::trainer::{TrainReport, Trainer};
use crate::util::Pcg64;

/// Number of random cases per property (kept modest: the suite has
/// hundreds of properties and CI runs on one core).
pub const DEFAULT_CASES: usize = 64;

/// RAII temporary directory: created unique (name + pid + counter, so
/// concurrent test binaries sharing a name never collide), removed on
/// drop.  Replaces the hand-rolled `temp_dir().join(...)` +
/// `remove_dir_all` dance the integration tests used to repeat.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn join<P: AsRef<Path>>(&self, name: P) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Fresh unique temp directory under the system temp root.
pub fn tmp_dir(name: &str) -> TempDir {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let c = COUNTER.fetch_add(1, Ordering::Relaxed);
    let path = std::env::temp_dir().join(format!(
        "bertdist_{name}_{}_{c}", std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&path);
    std::fs::create_dir_all(&path).expect("create temp dir");
    TempDir { path }
}

/// Fresh unique temp directory for checkpoint files/rotation dirs (the
/// resume tests' standard home).
pub fn tmp_ckpt_dir(name: &str) -> TempDir {
    tmp_dir(&format!("{name}_ckpt"))
}

/// Build a trainer for `cfg` and run it `steps` optimizer steps — the
/// shared setup of the resume/e2e tests.  Argument order mirrors
/// [`Trainer::new`] (`seq` before `batch`).
pub fn train_to_step(engine: &Engine, cfg: &RunConfig,
                     datasets: &[ShardedDataset], seq: usize, batch: usize,
                     steps: usize, total_steps_for_lr: usize)
                     -> anyhow::Result<(Trainer, TrainReport)> {
    let mut t = Trainer::new(engine, cfg.clone(), seq, batch)?;
    let report = t.run(datasets, steps, total_steps_for_lr)?;
    Ok((t, report))
}

/// Run `prop` on `cases` random inputs drawn by `gen`.  Panics with the
/// seed and case index on the first failure so it can be replayed.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> bool,
) {
    for case in 0..cases {
        let mut rng = Pcg64::with_stream(seed, case as u64);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n\
                 input = {input:?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result<(), String>` for a
/// descriptive failure message.
pub fn check_msg<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Pcg64::with_stream(seed, case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n\
                 input = {input:?}"
            );
        }
    }
}

/// Random f32 vector with magnitudes spanning many binades (including
/// denormal-range and large values — stress input for numerics code).
pub fn gen_f32_vec(rng: &mut Pcg64, min_len: usize, max_len: usize) -> Vec<f32> {
    let n = rng.range_usize(min_len, max_len + 1);
    (0..n)
        .map(|_| {
            let mag = rng.next_f64() * 24.0 - 12.0; // 2^-12 .. 2^12
            let v = 2.0f64.powf(mag) * if rng.chance(0.5) { -1.0 } else { 1.0 };
            v as f32
        })
        .collect()
}

/// Random u32 vector.
pub fn gen_u32_vec(rng: &mut Pcg64, min_len: usize, max_len: usize,
                   bound: u32) -> Vec<u32> {
    let n = rng.range_usize(min_len, max_len + 1);
    (0..n).map(|_| rng.gen_range(bound as u64) as u32).collect()
}

/// Random byte blob.
pub fn gen_bytes(rng: &mut Pcg64, min_len: usize, max_len: usize) -> Vec<u8> {
    let n = rng.range_usize(min_len, max_len + 1);
    (0..n).map(|_| rng.next_u32() as u8).collect()
}

/// Assert two f32 slices are elementwise close (absolute + relative).
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "allclose failed at [{i}]: {x} vs {y} (tol {tol})"
        );
    }
}

/// Max |a-b| over two slices (0 for empty).
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tmp_dirs_are_unique_and_cleaned_on_drop() {
        let a = tmp_dir("tk_unit");
        let b = tmp_dir("tk_unit");
        assert_ne!(a.path(), b.path());
        assert!(a.path().is_dir() && b.path().is_dir());
        std::fs::write(a.join("x"), b"1").unwrap();
        let kept = a.path().to_path_buf();
        drop(a);
        assert!(!kept.exists(), "dropped TempDir must be removed");
        assert!(b.path().is_dir());
    }

    #[test]
    fn check_passes_valid_property() {
        check("sum-commutes", 1, 32,
              |r| (r.gen_range(100) as i64, r.gen_range(100) as i64),
              |&(a, b)| a + b == b + a);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn check_reports_failures() {
        check("always-false", 2, 4, |r| r.next_u32(), |_| false);
    }

    #[test]
    fn gen_f32_spans_magnitudes() {
        let mut rng = Pcg64::new(3);
        let v = gen_f32_vec(&mut rng, 1000, 1000);
        let small = v.iter().filter(|x| x.abs() < 1e-2).count();
        let large = v.iter().filter(|x| x.abs() > 1e2).count();
        assert!(small > 50 && large > 50, "small={small} large={large}");
    }

    #[test]
    fn allclose_respects_tolerances() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0 - 1e-7], 1e-6, 0.0);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_catches_differences() {
        assert_allclose(&[1.0], &[1.1], 1e-6, 1e-6);
    }
}
