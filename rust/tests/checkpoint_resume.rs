//! ISSUE 4 (tentpole): exact-resume checkpointing.
//!
//! The headline property: train N optimizer steps uninterrupted, then
//! for checkpoint boundaries k train k steps, save, reconstruct a fresh
//! `Trainer` from disk, finish the run, and assert BITWISE-identical
//! params/m/v/scaler/loss history — swept across world sizes, flat and
//! hierarchical comm modes, prefetch on/off, and injected AMP-overflow
//! skips (the case the old `data_step = step` heuristic got wrong).
//!
//! Plus the corruption matrix (truncate at every v2 field boundary,
//! flip a byte in every section, the crash-leftover `.tmp` case), the
//! committed golden v1 fixture, and finetune-loop resume.  Training
//! tests require `make artifacts` and skip gracefully without them;
//! everything else runs artifact-free.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use bertdist::checkpoint::{self, verify_checkpoint, AsyncCheckpointWriter,
                           Checkpoint, CkptError, Fingerprint};
use bertdist::config::RunConfig;
use bertdist::coordinator::prepare_datasets;
use bertdist::data::corpus::SyntheticCorpus;
use bertdist::data::{build_shards, Vocab};
use bertdist::grad::sparsify::Sparsify;
use bertdist::precision::ScalerState;
use bertdist::runtime::Engine;
use bertdist::testkit::{tmp_ckpt_dir, tmp_dir, train_to_step};
use bertdist::topology::Topology;
use bertdist::trainer::{CommMode, Trainer};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn make_data(dir: &Path, vocab_size: usize, shards: usize) {
    let docs = SyntheticCorpus::new(9, 2_000).documents(24, 8, 10);
    let vocab = Vocab::from_documents(&docs, vocab_size);
    vocab.save(&dir.join("vocab.txt")).unwrap();
    build_shards(&docs, &vocab, shards, dir, "train", 9).unwrap();
}

fn base_cfg(topo: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.train.preset = "bert-micro".into();
    cfg.train.variant = "fused_f32".into();
    cfg.train.lr = 1e-3;
    cfg.train.warmup_steps = 2;
    cfg.train.accum_steps = 2;
    cfg.train.log_every = 0;
    cfg.cluster.topo = Topology::parse(topo).unwrap();
    cfg
}

// ---- log capture (the v1 "inexact data position" warning) ----

static LOG_LINES: Mutex<Vec<String>> = Mutex::new(Vec::new());

struct Capture;

impl log::Log for Capture {
    fn enabled(&self, _: &log::Metadata) -> bool {
        true
    }
    fn log(&self, record: &log::Record) {
        LOG_LINES.lock().unwrap().push(format!("{}", record.args()));
    }
    fn flush(&self) {}
}

static CAPTURE: Capture = Capture;

fn install_capture() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let _ = log::set_logger(&CAPTURE);
        log::set_max_level(log::LevelFilter::Warn);
    });
}

// ---- bitwise state comparison ----

fn assert_state_bitwise(got: &Checkpoint, want: &Checkpoint, ctx: &str) {
    assert_eq!(got.step, want.step, "{ctx}: step");
    assert_eq!(got.data_step, want.data_step, "{ctx}: data_step");
    assert_eq!(got.scaler, want.scaler, "{ctx}: scaler state");
    for (name, a, b) in [("params", &got.params, &want.params),
                         ("m", &got.m, &want.m), ("v", &got.v, &want.v)] {
        assert_eq!(a.len(), b.len(), "{ctx}: {name} length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{ctx}: {name}[{i}] diverged: {x} vs {y}");
        }
    }
    // v2.2: the per-rank error-feedback residuals are training state
    // too — a sparsified stream only resumes bitwise if they match.
    assert_eq!(got.ef_residuals.len(), want.ef_residuals.len(),
               "{ctx}: ef residual rank count");
    for (r, (a, b)) in got.ef_residuals
        .iter()
        .zip(want.ef_residuals.iter())
        .enumerate() {
        assert_eq!(a.len(), b.len(), "{ctx}: ef[{r}] length");
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{ctx}: ef[{r}][{i}] diverged: {x} vs {y}");
        }
    }
}

fn losses(points: &[(usize, f64)]) -> Vec<f64> {
    points.iter().map(|p| p.1).collect()
}

fn assert_losses_bitwise(got: &[f64], want: &[f64], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: loss history length");
    for (i, (x, y)) in got.iter().zip(want.iter()).enumerate() {
        assert!(x.to_bits() == y.to_bits()
                    || (x.is_nan() && y.is_nan()),
                "{ctx}: loss[{i}] diverged: {x} vs {y}");
    }
}

// ---- the resume-equivalence property (the archetype) ----

/// Train `n` steps uninterrupted; for each boundary `k` in `ks` train
/// `k` steps, save to disk, rebuild a fresh trainer from the file,
/// finish, and require bitwise-identical end state + loss history.
fn check_resume_equivalence(topo: &str, mode: CommMode, prefetch: usize,
                           inject_skips: bool, sparsify: Sparsify,
                           n: usize, ks: &[usize]) {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let tag = format!("{topo}/{mode:?}/pf{prefetch}/skips={inject_skips}/\
                       {sparsify}");
    let data = tmp_dir(&format!("resume_{topo}_{mode:?}_{prefetch}_\
                                 {inject_skips}_{sparsify}"));
    make_data(data.path(), 512, 4);
    let engine = Engine::cpu(&art).unwrap();
    let mut cfg = base_cfg(topo);
    cfg.train.comm_mode = mode;
    cfg.train.prefetch_depth = prefetch;
    cfg.train.sparsify = sparsify;
    // topk on a multi-machine topology puts real error-feedback state
    // in every checkpoint; on one machine the knob is inert
    let sparsify_live = matches!(sparsify, Sparsify::TopK(_))
        && cfg.cluster.topo.machines > 1;
    if inject_skips {
        // An astronomically large initial scale overflows the scaled
        // loss in f32 for the first step(s): REAL AMP skips through the
        // real path — steps that consume data but apply nothing, the
        // exact case the legacy `data_step = step` guess replayed
        // wrongly.
        cfg.train.init_loss_scale = 1e38;
    }
    let world = cfg.cluster.topo.world_size();
    let datasets = prepare_datasets(data.path(), world).unwrap();

    // uninterrupted baseline
    let (t, rep) = train_to_step(&engine, &cfg, &datasets, 32, 2, n, n)
        .unwrap();
    let want = t.checkpoint();
    let want_losses = losses(&rep.loss.points);
    if inject_skips {
        assert!(rep.skipped_steps > 0,
                "{tag}: skip injection did not trigger");
        assert!(want.step < want.data_step,
                "{tag}: skipped steps must leave step behind data_step");
    }
    if sparsify_live {
        assert_eq!(want.ef_residuals.len(), world,
                   "{tag}: a live sparsifier must snapshot one residual \
                    per rank");
        assert!(want.ef_residuals
                    .iter()
                    .any(|r| r.iter().any(|&x| x != 0.0)),
                "{tag}: a lossy ratio must leave real mass in the \
                 residuals");
    } else {
        assert!(want.ef_residuals.is_empty(),
                "{tag}: dense/inert runs must not checkpoint residuals");
    }
    drop(t);

    let ckdir = tmp_ckpt_dir(&format!("resume_{topo}_{mode:?}_{prefetch}_\
                                       {inject_skips}"));
    for &k in ks {
        let ctx = format!("{tag} k={k}");
        // run k steps and checkpoint through the real file format
        let (tk, rep_a) =
            train_to_step(&engine, &cfg, &datasets, 32, 2, k, n).unwrap();
        let path = ckdir.join(&format!("k{k}.bckp"));
        tk.save(&path).unwrap();
        drop(tk);

        // fresh trainer, restored purely from disk
        let mut resumed = Trainer::new(&engine, cfg.clone(), 32, 2).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert!(loaded.exact_data_position);
        assert!(loaded.fingerprint.is_some(), "{ctx}: v2 must fingerprint");
        assert_eq!(loaded.fingerprint.unwrap().sparsify, sparsify,
                   "{ctx}: the fingerprint must carry the knob");
        if sparsify_live {
            // the residuals round-trip through the real file format at
            // EVERY boundary k, one full-length vector per rank
            assert_eq!(loaded.ef_residuals.len(), world, "{ctx}: ef ranks");
            for (r, ef) in loaded.ef_residuals.iter().enumerate() {
                assert_eq!(ef.len(), loaded.params.len(),
                           "{ctx}: ef[{r}] must span the model");
            }
        } else {
            assert!(loaded.ef_residuals.is_empty(), "{ctx}: ef section");
        }
        resumed.restore(loaded).unwrap();
        assert_eq!(resumed.data_step(), k,
                   "{ctx}: data_step counts attempted steps");
        let rep_b = resumed.run(&datasets, n - k, n).unwrap();

        assert_state_bitwise(&resumed.checkpoint(), &want, &ctx);
        let mut got_losses = losses(&rep_a.loss.points);
        got_losses.extend(losses(&rep_b.loss.points));
        assert_losses_bitwise(&got_losses, &want_losses, &ctx);
    }
}

#[test]
fn resume_is_bitwise_identical_at_every_boundary() {
    // the full k-sweep on the base configuration
    let ks: Vec<usize> = (1..6).collect();
    check_resume_equivalence("1M2G", CommMode::Flat, 2, false,
                             Sparsify::None, 6, &ks);
}

#[test]
fn resume_equivalence_with_injected_amp_skips_full_sweep() {
    // every boundary again, with overflow skips in the stream — the
    // checkpoint may land between two skips, mid-backoff
    let ks: Vec<usize> = (1..6).collect();
    check_resume_equivalence("1M2G", CommMode::Flat, 2, true,
                             Sparsify::None, 6, &ks);
}

#[test]
fn resume_equivalence_across_worlds_comm_modes_and_prefetch() {
    // one mid-run boundary across the config matrix: world 1..4,
    // flat + hierarchical, prefetch off/on, skips off/on
    for (topo, mode) in [("1M1G", CommMode::Flat),
                         ("1M2G", CommMode::Flat),
                         ("1M3G", CommMode::Flat),
                         ("2M2G", CommMode::Flat),
                         ("2M2G", CommMode::Hierarchical)] {
        for prefetch in [0usize, 2] {
            for inject in [false, true] {
                check_resume_equivalence(topo, mode, prefetch, inject,
                                         Sparsify::None, 4, &[2]);
            }
        }
    }
}

#[test]
fn resume_equivalence_with_topk_sparsify_carries_ef_bitwise() {
    // ISSUE 10: the sparsify=topk(0.1) axis of the sweep.  The lossy
    // exchange makes the error-feedback residuals REAL state: they must
    // round-trip bitwise through the file format at every boundary k,
    // or the resumed stream diverges from the uninterrupted one.
    let ks: Vec<usize> = (1..5).collect();
    check_resume_equivalence("2M2G", CommMode::Hierarchical, 2, false,
                             Sparsify::TopK(0.1), 5, &ks);
}

#[test]
fn resume_equivalence_topk_flat_mode_and_inert_single_machine() {
    // flat comm mode still sparsifies its (network-crossing) world
    // ring; a single-machine topology must stay inert — the knob is
    // set but no residuals ever appear in the checkpoint
    check_resume_equivalence("2M2G", CommMode::Flat, 0, false,
                             Sparsify::TopK(0.1), 4, &[2]);
    check_resume_equivalence("1M2G", CommMode::Flat, 2, false,
                             Sparsify::TopK(0.1), 4, &[2]);
}

#[test]
fn restore_rejects_fingerprint_mismatch_before_touching_state() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    let cfg = base_cfg("1M1G");
    let saver = Trainer::new(&engine, cfg.clone(), 32, 2).unwrap();
    let ck = saver.checkpoint();

    // a run with a different seed must refuse the checkpoint
    let mut other = cfg.clone();
    other.train.seed = cfg.train.seed + 1;
    let mut t = Trainer::new(&engine, other, 32, 2).unwrap();
    let before = t.checkpoint();
    let err = t.restore(ck.clone()).unwrap_err();
    assert!(err.to_string().contains("fingerprint"), "{err}");
    assert!(err.to_string().contains("seed"), "{err}");
    // refusal left the trainer untouched (never partial state)
    assert_state_bitwise(&t.checkpoint(), &before, "mismatch refusal");

    // same config accepts it
    let mut t = Trainer::new(&engine, cfg, 32, 2).unwrap();
    t.restore(ck).unwrap();
}

#[test]
fn restore_rejects_a_different_corpus_manifest() {
    // v2.1: the fingerprint carries a shard-manifest hash, so resuming
    // the same config over a DIFFERENT dataset fails loudly — and an
    // unknown manifest on either side (bare snapshots, tests) never
    // blocks.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    let cfg = base_cfg("1M1G");
    let mut saver = Trainer::new(&engine, cfg.clone(), 32, 2).unwrap();
    saver.set_data_manifest(0xAAAA);
    let ck = saver.checkpoint();
    assert_eq!(ck.fingerprint.unwrap().data_manifest, 0xAAAA);

    // a run over a different corpus refuses the checkpoint untouched
    let mut t = Trainer::new(&engine, cfg.clone(), 32, 2).unwrap();
    t.set_data_manifest(0xBBBB);
    let before = t.checkpoint();
    let err = t.restore(ck.clone()).unwrap_err();
    assert!(err.to_string().contains("corpus"), "{err}");
    assert_state_bitwise(&t.checkpoint(), &before, "corpus refusal");

    // the same corpus accepts it; so does a manifest-less run
    let mut same = Trainer::new(&engine, cfg.clone(), 32, 2).unwrap();
    same.set_data_manifest(0xAAAA);
    same.restore(ck.clone()).unwrap();
    let mut unknown = Trainer::new(&engine, cfg, 32, 2).unwrap();
    unknown.restore(ck).unwrap();
}

#[test]
fn v1_restore_falls_back_to_step_and_warns() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    install_capture();
    let engine = Engine::cpu(&art).unwrap();
    let cfg = base_cfg("1M1G");
    let mut t = Trainer::new(&engine, cfg, 32, 2).unwrap();
    let n = t.checkpoint().params.len();
    // what loading a v1 file yields: no fingerprint, inexact position
    let mut legacy = Checkpoint::new(n);
    legacy.step = 5;
    legacy.data_step = 999; // must be ignored by the fallback
    legacy.scaler = ScalerState::legacy(2048.0);
    legacy.fingerprint = None;
    legacy.exact_data_position = false;
    t.restore(legacy).unwrap();
    assert_eq!(t.step, 5);
    assert_eq!(t.data_step(), 5, "v1 fallback is data_step = step");
    assert_eq!(t.scaler.scale(), 2048.0);
    let lines = LOG_LINES.lock().unwrap();
    assert!(lines.iter().any(|l| l.contains("inexact data position")),
            "one-line warning expected, got {lines:?}");
}

// ---- golden v1 fixture (committed file) ----

#[test]
fn golden_v1_fixture_still_loads_with_legacy_fallback() {
    install_capture();
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data/golden_v1.bckp");
    let c = Checkpoint::load(&path).unwrap();
    assert_eq!(c.step, 7);
    assert_eq!(c.data_step, 7, "legacy fallback: data_step = step");
    assert!(!c.exact_data_position);
    assert!(c.fingerprint.is_none());
    assert_eq!(c.loss_scale(), 1024.0);
    assert_eq!(c.scaler, ScalerState::legacy(1024.0));
    assert_eq!(c.params, vec![0.5, -1.5, 2.0, -0.25]);
    assert_eq!(c.m, vec![0.1, 0.2, 0.3, 0.4]);
    assert_eq!(c.v, vec![1.0, 2.0, 3.0, 4.0]);
    let lines = LOG_LINES.lock().unwrap();
    assert!(lines.iter().any(|l| l.contains("inexact data position")),
            "v1 load must warn about the inexact data position");
}

// ---- corruption matrix ----

#[test]
fn corruption_matrix_truncate_and_flip_every_section() {
    let dir = tmp_ckpt_dir("corruption");
    let n = 6usize;
    let mut c = Checkpoint::new(n);
    c.step = 11;
    c.data_step = 13;
    c.fingerprint = Some(Fingerprint::of(&RunConfig::default(), 8, 128));
    for (i, x) in c.params.iter_mut().enumerate() {
        *x = i as f32 + 0.5;
    }
    let good = dir.join("good.bckp");
    c.save(&good).unwrap();
    let bytes = std::fs::read(&good).unwrap();
    assert_eq!(bytes.len(), checkpoint::v2_file_len(n));

    for (name, range) in checkpoint::v2_sections(n) {
        // truncate at the section's start boundary
        let bad = dir.join(format!("trunc_{name}.bckp"));
        std::fs::write(&bad, &bytes[..range.start]).unwrap();
        let err = Checkpoint::load(&bad).map(|_| ()).unwrap_err();
        assert!(
            matches!(err, CkptError::BadMagic | CkptError::Corrupt
                          | CkptError::SizeMismatch),
            "truncation at {name} ({}) must be a clean load error, got \
             {err:?}", range.start
        );
        // flip one byte inside the section (skip zero-length sections)
        if range.is_empty() {
            continue;
        }
        let mut flipped = bytes.clone();
        flipped[range.start] ^= 0x01;
        let bad = dir.join(format!("flip_{name}.bckp"));
        std::fs::write(&bad, &flipped).unwrap();
        let err = Checkpoint::load(&bad).map(|_| ()).unwrap_err();
        if name == "magic" {
            assert!(matches!(err, CkptError::BadMagic), "{name}: {err:?}");
        } else {
            assert!(matches!(err, CkptError::Corrupt), "{name}: {err:?}");
        }
    }
    // appending a byte breaks the CRC framing too
    let mut longer = bytes.clone();
    longer.push(0);
    let bad = dir.join("longer.bckp");
    std::fs::write(&bad, &longer).unwrap();
    assert!(Checkpoint::load(&bad).is_err());
}

#[test]
fn corruption_matrix_covers_the_v22_ef_section() {
    // ISSUE 10: a checkpoint carrying error-feedback residuals grows an
    // `ef` section between `v` and the CRC — the same truncate/flip
    // matrix must hold over the extended layout, and the intact file
    // must verify and round-trip the residuals bitwise.
    let dir = tmp_ckpt_dir("corruption_ef");
    let n = 6usize;
    let mut c = Checkpoint::new(n);
    c.step = 11;
    c.data_step = 13;
    let mut cfg = RunConfig::default();
    cfg.train.sparsify = Sparsify::TopK(0.1);
    c.fingerprint = Some(Fingerprint::of(&cfg, 8, 128));
    c.ef_residuals = vec![vec![0.25f32; n], vec![-1.5f32; n]];
    for (i, x) in c.params.iter_mut().enumerate() {
        *x = i as f32 + 0.5;
    }
    let good = dir.join("good.bckp");
    c.save(&good).unwrap();
    let bytes = std::fs::read(&good).unwrap();
    let ef_lens = [n, n];
    assert_eq!(bytes.len(), checkpoint::v2_file_len_with_ef(n, &ef_lens));
    assert_eq!(verify_checkpoint(&good).unwrap(), bytes.len() as u64);
    let loaded = Checkpoint::load(&good).unwrap();
    assert_eq!(loaded.ef_residuals, c.ef_residuals,
               "residuals must round-trip bitwise");
    assert_eq!(loaded.fingerprint.unwrap().sparsify, Sparsify::TopK(0.1));

    for (name, range) in checkpoint::v2_sections_with_ef(n, &ef_lens) {
        // truncate at the section's start boundary
        let bad = dir.join(format!("trunc_{name}.bckp"));
        std::fs::write(&bad, &bytes[..range.start]).unwrap();
        let err = Checkpoint::load(&bad).map(|_| ()).unwrap_err();
        assert!(
            matches!(err, CkptError::BadMagic | CkptError::Corrupt
                          | CkptError::SizeMismatch),
            "truncation at {name} ({}) must be a clean load error, got \
             {err:?}", range.start
        );
        if range.is_empty() {
            continue;
        }
        // flip one byte inside the section
        let mut flipped = bytes.clone();
        flipped[range.start] ^= 0x01;
        let bad = dir.join(format!("flip_{name}.bckp"));
        std::fs::write(&bad, &flipped).unwrap();
        let err = Checkpoint::load(&bad).map(|_| ()).unwrap_err();
        if name == "magic" {
            assert!(matches!(err, CkptError::BadMagic), "{name}: {err:?}");
        } else {
            assert!(matches!(err, CkptError::Corrupt), "{name}: {err:?}");
        }
    }
    // a tear INSIDE the ef section (mid-residual, off every boundary)
    // fails cleanly too
    let ef_range = checkpoint::v2_sections_with_ef(n, &ef_lens)
        .into_iter()
        .find(|(name, _)| *name == "ef")
        .unwrap()
        .1;
    let bad = dir.join("trunc_mid_ef.bckp");
    std::fs::write(&bad, &bytes[..ef_range.start + 6]).unwrap();
    let err = Checkpoint::load(&bad).map(|_| ()).unwrap_err();
    assert!(matches!(err, CkptError::Corrupt | CkptError::SizeMismatch),
            "mid-ef tear: {err:?}");
}

#[test]
fn crash_leftover_tmp_never_shadows_a_real_checkpoint() {
    // the rename-never-happened case: a stale `.tmp` sits next to the
    // real rotation files
    let dir = tmp_ckpt_dir("tmpcrash");
    let mut c = Checkpoint::new(4);
    c.step = 6;
    c.data_step = 6;
    c.save(&dir.join(checkpoint::checkpoint_file_name(6))).unwrap();
    std::fs::write(dir.join("ckpt-0000000042.tmp"), b"half a checkpoint")
        .unwrap();
    let latest = checkpoint::latest_checkpoint(dir.path()).unwrap()
        .expect("real checkpoint visible");
    assert!(latest.ends_with(checkpoint::checkpoint_file_name(6)));
    assert_eq!(Checkpoint::load(&latest).unwrap().step, 6);
    // a fresh writer on the dir clears the leftover up front
    let w = AsyncCheckpointWriter::new(dir.path(), 3).unwrap();
    drop(w);
    assert!(!dir.join("ckpt-0000000042.tmp").exists());
    assert!(dir.join(&checkpoint::checkpoint_file_name(6)).exists());
}

// ---- reshaped (elastic) restore ----

/// The elastic-restore contract, per world pair: train on `from`, save
/// through the real file format, and restore onto `to`.
///
/// Asserted, in order: the strict gate refuses the topology change and
/// leaves the target untouched; the reshape gate accepts it and the
/// restore itself is BITWISE (params/m/v/scaler/step/data_step); and
/// the reshaped stream is itself exactly resumable — a strict
/// save/restore round trip one step after the reshape lands bitwise on
/// the same final state as running straight through on the new world.
fn check_reshape_restore(from: &str, to: &str) {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let ctx = format!("reshape {from}->{to}");
    let data = tmp_dir(&format!("reshape_{from}_{to}"));
    // 8 shards: enough for the largest world in the matrix (2M4G)
    make_data(data.path(), 512, 8);
    let engine = Engine::cpu(&art).unwrap();
    let cfg_a = base_cfg(from);
    let cfg_b = base_cfg(to);
    let datasets_a =
        prepare_datasets(data.path(), cfg_a.cluster.topo.world_size())
            .unwrap();
    let datasets_b =
        prepare_datasets(data.path(), cfg_b.cluster.topo.world_size())
            .unwrap();

    // 2 of 4 steps on the old world, through the real file format
    let (ta, _) =
        train_to_step(&engine, &cfg_a, &datasets_a, 32, 2, 2, 4).unwrap();
    let ckdir = tmp_ckpt_dir(&format!("reshape_{from}_{to}"));
    let path = ckdir.join("boundary.bckp");
    ta.save(&path).unwrap();
    drop(ta);
    let ck = Checkpoint::load(&path).unwrap();

    // strict gate refuses the topology change, target untouched
    let mut tb = Trainer::new(&engine, cfg_b.clone(), 32, 2).unwrap();
    let before = tb.checkpoint();
    let err = tb.restore(ck.clone()).unwrap_err();
    assert!(err.to_string().contains("topology"), "{ctx}: {err}");
    assert_state_bitwise(&tb.checkpoint(), &before,
                         &format!("{ctx}: strict refusal"));

    // reshape gate accepts; the restore itself is bitwise
    tb.restore_reshape(ck.clone()).unwrap();
    assert_state_bitwise(&tb.checkpoint(), &ck,
                         &format!("{ctx}: restore-time state"));
    assert_eq!(tb.data_step(), 2, "{ctx}: stream restarts at data_step");

    // finish the run on the new world
    tb.run(&datasets_b, 2, 4).unwrap();
    let straight_through = tb.checkpoint();
    drop(tb);

    // the reshaped stream is exactly resumable: one step after the
    // reshape, a STRICT save/restore round trip (the snapshot now
    // carries the new topology) must land bitwise on the same end state
    let mut tc = Trainer::new(&engine, cfg_b.clone(), 32, 2).unwrap();
    tc.restore_reshape(ck).unwrap();
    tc.run(&datasets_b, 1, 4).unwrap();
    let mid = ckdir.join("mid.bckp");
    tc.save(&mid).unwrap();
    drop(tc);
    let mut td = Trainer::new(&engine, cfg_b, 32, 2).unwrap();
    td.restore(Checkpoint::load(&mid).unwrap()).unwrap();
    td.run(&datasets_b, 1, 4).unwrap();
    assert_state_bitwise(&td.checkpoint(), &straight_through,
                         &format!("{ctx}: reshaped stream resumability"));
}

#[test]
fn reshaped_restore_world_4_to_2() {
    check_reshape_restore("1M4G", "1M2G");
}

#[test]
fn reshaped_restore_world_2_to_4() {
    check_reshape_restore("1M2G", "1M4G");
}

#[test]
fn reshaped_restore_2m4g_to_1m4g() {
    // node loss: same per-node shape, half the machines
    check_reshape_restore("2M4G", "1M4G");
}

#[test]
fn verify_rejects_truncation_at_every_section_boundary() {
    // the ledger's post-write verify must catch a checkpoint torn at
    // ANY v2 field boundary (the mid-verify crash case), and report the
    // full byte count for an intact file
    let dir = tmp_ckpt_dir("verify_trunc");
    let n = 6usize;
    let mut c = Checkpoint::new(n);
    c.step = 11;
    c.data_step = 13;
    c.fingerprint = Some(Fingerprint::of(&RunConfig::default(), 8, 128));
    let good = dir.join("good.bckp");
    c.save(&good).unwrap();
    let bytes = std::fs::read(&good).unwrap();
    assert_eq!(verify_checkpoint(&good).unwrap(), bytes.len() as u64);

    for (name, range) in checkpoint::v2_sections(n) {
        let bad = dir.join(format!("vtrunc_{name}.bckp"));
        std::fs::write(&bad, &bytes[..range.start]).unwrap();
        let err = verify_checkpoint(&bad).map(|_| ()).unwrap_err();
        assert!(
            matches!(err, CkptError::BadMagic | CkptError::Corrupt
                          | CkptError::SizeMismatch),
            "verify of a file truncated at {name} ({}) must fail \
             cleanly, got {err:?}", range.start
        );
    }
    // a torn tail mid-section (not on a boundary) fails too
    let bad = dir.join("vtrunc_mid.bckp");
    std::fs::write(&bad, &bytes[..bytes.len() - 7]).unwrap();
    assert!(verify_checkpoint(&bad).is_err());
}

// ---- finetune-loop resume ----

#[test]
fn finetune_resume_is_bitwise_identical() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use bertdist::finetune::{run_finetune, run_finetune_ckpt, FinetuneCkpt};
    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let mut rng = bertdist::util::Pcg64::new(4);
    let pre = bertdist::trainer::init_params(&model.layout, &mut rng);
    let (steps, batch, seq, lr, seed) = (8usize, 2usize, 32usize, 1e-3, 9);

    let full = run_finetune(&engine, "bert-micro", &pre, steps, batch, seq,
                            lr, seed).unwrap();

    // interrupted at step 4, resumed from the rotation dir
    let dir = tmp_ckpt_dir("finetune_resume");
    let opts = |resume| FinetuneCkpt {
        dir: dir.path(),
        save_every: 4,
        keep_last: 2,
        resume,
    };
    run_finetune_ckpt(&engine, "bert-micro", &pre, 4, batch, seq, lr, seed,
                      Some(opts(false))).unwrap();
    let resumed = run_finetune_ckpt(&engine, "bert-micro", &pre, steps,
                                    batch, seq, lr, seed, Some(opts(true)))
        .unwrap();
    assert_eq!(resumed.final_params.len(), full.final_params.len());
    for (i, (a, b)) in resumed
        .final_params
        .iter()
        .zip(full.final_params.iter())
        .enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(),
                   "finetune param [{i}] diverged: {a} vs {b}");
    }
    // resumed run recorded only the back half of the curve
    assert_eq!(resumed.loss.points.first().map(|p| p.0), Some(4));
}
