//! CLI smoke tests: drive the real `bertdist` binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_bertdist"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

#[test]
fn no_args_prints_usage() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("shard-data"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn unknown_option_is_rejected() {
    let out = bin().args(["cost", "--dayz", "3"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--dayz"));
}

#[test]
fn cost_command_prints_paper_tables() {
    let out = bin().arg("cost").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("$624000"));
    assert!(text.contains("$4768000"));
    assert!(text.contains("25804.8"));
}

#[test]
fn scaling_command_reports_headline() {
    let out = bin().args(["scaling", "--mode", "multinode"]).output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("32M8G"));
    assert!(text.contains("headline"));
}

#[test]
fn simulate_command_renders_timeline() {
    let out = bin()
        .args(["simulate", "--topo", "2M1G", "--accum", "2",
               "--print-topology"])
        .output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("compute utilization"));
    assert!(text.contains("Node 0"));
    assert!(text.contains("gpu"));
}

#[test]
fn profile_grads_matches_figure4() {
    let out = bin().args(["profile-grads", "--preset", "bert-large"])
        .output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("attention"));
    assert!(text.contains("dense"));
}

#[test]
fn profile_grads_emits_exchange_trace() {
    // The --trace path runs REAL pooled steps (no XLA artifacts needed)
    // and writes PCIe/network chrome-trace spans.
    let path = std::env::temp_dir().join("bertdist_cli_exchange.json");
    let _ = std::fs::remove_file(&path);
    let out = bin()
        .args(["profile-grads", "--preset", "bert-micro", "--trace",
               path.to_str().unwrap(), "--topology", "2M2G", "--comm-mode",
               "hierarchical", "--steps", "2", "--accum", "1",
               "--bucket-elems", "65536"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("exchange profile"));
    assert!(text.contains("hierarchical"));
    let trace = std::fs::read_to_string(&path).unwrap();
    assert!(trace.contains("traceEvents"));
    assert!(trace.contains("pcie") && trace.contains("net"), "{trace}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn bad_comm_mode_is_rejected() {
    let out = bin()
        .args(["profile-grads", "--preset", "bert-micro", "--comm-mode",
               "rings"])
        .output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("comm-mode"));
}

#[test]
fn amp_demo_runs() {
    let out = bin().args(["amp-demo", "--steps", "50"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fp16"));
    assert!(text.contains("scale"));
}

#[test]
fn shard_then_train_roundtrip() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let dir = std::env::temp_dir().join("bertdist_cli_train");
    let _ = std::fs::remove_dir_all(&dir);
    let out = bin()
        .args(["shard-data", "--out", dir.to_str().unwrap(), "--docs", "12",
               "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(["train", "--preset", "bert-micro", "--topo", "1M2G",
               "--steps", "4", "--accum", "1", "--batch", "2", "--seq",
               "32", "--data-dir", dir.to_str().unwrap(), "--log-every",
               "2", "--lr", "1e-3"])
        .output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(),
            "stdout:\n{text}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("phase 1 done"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn train_resume_missing_file_is_a_clean_error() {
    // --resume is validated before data/engine setup: a missing file
    // must exit with the "nothing restorable" code and a clear message,
    // no artifacts required.
    let out = bin()
        .args(["train", "--resume", "/nonexistent/ckpt.bckp", "--steps",
               "1"])
        .output().unwrap();
    assert_eq!(out.status.code(), Some(5));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("cannot resume from"), "{err}");
    assert!(err.contains("/nonexistent/ckpt.bckp"), "{err}");
}

#[test]
fn train_resume_empty_dir_is_a_clean_error() {
    let dir = bertdist::testkit::tmp_ckpt_dir("cli_empty_resume");
    let out = bin()
        .args(["train", "--resume", dir.path().to_str().unwrap()])
        .output().unwrap();
    // empty dir = "nothing restorable", not a generic failure
    assert_eq!(out.status.code(), Some(5));
    assert!(String::from_utf8_lossy(&out.stderr)
                .contains("no ckpt-*.bckp files"));
}

#[test]
fn train_resume_fingerprint_mismatch_is_a_clean_error() {
    // craft a v2 checkpoint pinned to topology 2M2G, then try to resume
    // it on 1M1G: the config fingerprint must refuse, nonzero exit,
    // before any artifacts or data are needed
    use bertdist::checkpoint::{Checkpoint, Fingerprint};
    use bertdist::config::RunConfig;
    use bertdist::topology::Topology;
    let dir = bertdist::testkit::tmp_ckpt_dir("cli_fp_mismatch");
    let mut cfg = RunConfig::default();
    cfg.cluster.topo = Topology::parse("2M2G").unwrap();
    let mut ck = Checkpoint::new(16);
    ck.fingerprint = Some(Fingerprint::of(&cfg, 8, 128));
    let path = dir.join("pinned.bckp");
    ck.save(&path).unwrap();
    let out = bin()
        .args(["train", "--resume", path.to_str().unwrap(), "--topo",
               "1M1G"])
        .output().unwrap();
    // mismatch taxonomy: exit 3 = fix the config, not the disk
    assert_eq!(out.status.code(), Some(3));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fingerprint"), "{err}");
    assert!(err.contains("topology"), "{err}");
}

#[test]
fn train_resume_falls_back_past_a_corrupt_newest_checkpoint() {
    // the keep-last-K rotation's recovery depth: when the newest file
    // is unreadable (e.g. power loss), --resume warns and uses the
    // previous intact one instead of refusing to start
    use bertdist::checkpoint::{self, Checkpoint, Fingerprint};
    use bertdist::config::RunConfig;
    let dir = bertdist::testkit::tmp_ckpt_dir("cli_fallback");
    let empty = bertdist::testkit::tmp_dir("cli_fallback_nodata");
    let mut ck = Checkpoint::new(8);
    ck.step = 3;
    ck.data_step = 3;
    ck.fingerprint = Some(Fingerprint::of(&RunConfig::default(), 8, 128));
    ck.save(&dir.join(checkpoint::checkpoint_file_name(3))).unwrap();
    let mut bad =
        std::fs::read(dir.join(checkpoint::checkpoint_file_name(3)))
            .unwrap();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x40;
    std::fs::write(dir.join(checkpoint::checkpoint_file_name(9)), &bad)
        .unwrap();
    let out = bin()
        .args(["train", "--resume", dir.path().to_str().unwrap(),
               "--data-dir", empty.path().to_str().unwrap()])
        .output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("warning: cannot read"), "{stderr}");
    assert!(stdout.contains("resume checkpoint"), "{stdout}");
    assert!(stdout.contains("step 3"), "{stdout}");
    // the resume itself succeeded BEFORE data/engine setup; the run
    // then stops at the (deliberately empty) data dir
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr.contains("no data at"), "{stderr}");
}

#[test]
fn train_resume_single_corrupt_file_exits_with_corrupt_code() {
    // a single named checkpoint with flipped bytes has no older sibling
    // to fall back to: exit 4 = fix the disk
    use bertdist::checkpoint::{Checkpoint, Fingerprint};
    use bertdist::config::RunConfig;
    let dir = bertdist::testkit::tmp_ckpt_dir("cli_corrupt_single");
    let mut ck = Checkpoint::new(8);
    ck.fingerprint = Some(Fingerprint::of(&RunConfig::default(), 8, 128));
    let path = dir.join("only.bckp");
    ck.save(&path).unwrap();
    let mut bad = std::fs::read(&path).unwrap();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    let out = bin()
        .args(["train", "--resume", path.to_str().unwrap(), "--steps",
               "1"])
        .output().unwrap();
    assert_eq!(out.status.code(), Some(4));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("cannot resume from"), "{err}");
}

#[test]
fn train_resume_never_selects_a_ledger_unverified_checkpoint() {
    // the newest file has GOOD bytes now, but the ledger recorded that
    // it failed its post-write verify — the torn write may have been
    // "repaired" by a later partial flush.  --resume must not trust it:
    // warn and select the newest ledger-clean candidate instead.
    use bertdist::checkpoint::{self, Checkpoint, Fingerprint, Ledger,
                               LedgerEntry};
    use bertdist::config::RunConfig;
    let dir = bertdist::testkit::tmp_ckpt_dir("cli_ledger_skip");
    let empty = bertdist::testkit::tmp_dir("cli_ledger_skip_nodata");
    let fp = Fingerprint::of(&RunConfig::default(), 8, 128);
    for (step, data_step) in [(3u64, 3u64), (9, 9)] {
        let mut ck = Checkpoint::new(8);
        ck.step = step;
        ck.data_step = data_step;
        ck.fingerprint = Some(fp);
        ck.save(&dir.join(checkpoint::checkpoint_file_name(data_step)))
            .unwrap();
    }
    let mut ledger = Ledger::default();
    ledger.record(LedgerEntry {
        file: checkpoint::checkpoint_file_name(9),
        step: 9,
        data_step: 9,
        bytes: 0,
        verified: false,
    });
    ledger.save(&dir).unwrap();
    let out = bin()
        .args(["train", "--resume", dir.path().to_str().unwrap(),
               "--data-dir", empty.path().to_str().unwrap()])
        .output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("marked unverified"), "{stderr}");
    assert!(stdout.contains("resume checkpoint"), "{stdout}");
    assert!(stdout.contains("step 3"), "{stdout}");
    // resume selection succeeded; the run then stops at the
    // (deliberately empty) data dir
    assert_eq!(out.status.code(), Some(1));
    assert!(stderr.contains("no data at"), "{stderr}");
}

#[test]
fn train_inject_fail_restarts_reshaped_and_matches_clean_run() {
    // the elasticity contract end to end: a deterministic mid-run
    // failure on rank 1 is caught by --max-restarts, the run relaunches
    // on the surviving --restart-topo world from the newest
    // ledger-verified rotation checkpoint (losing at most --save-every
    // steps of progress), and the final parameters are bitwise-equal to
    // a clean run restarted at the same boundary on that same world.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use bertdist::checkpoint::{self, Checkpoint};
    let data = bertdist::testkit::tmp_dir("cli_elastic_data");
    let rot_a = bertdist::testkit::tmp_ckpt_dir("cli_elastic_rot_a");
    let rot_b = bertdist::testkit::tmp_ckpt_dir("cli_elastic_rot_b");
    let outdir = bertdist::testkit::tmp_dir("cli_elastic_out");
    let out = bin()
        .args(["shard-data", "--out", data.path().to_str().unwrap(),
               "--docs", "12", "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let train_args = |topo: &str| {
        vec!["train".to_string(), "--preset".into(), "bert-micro".into(),
             "--topo".into(), topo.into(), "--steps".into(), "6".into(),
             "--accum".into(), "1".into(), "--batch".into(), "2".into(),
             "--seq".into(), "32".into(), "--lr".into(), "1e-3".into(),
             "--log-every".into(), "0".into(),
             "--data-dir".into(), data.path().to_str().unwrap().into()]
    };

    // run A: a 6-step 1M2G run that dies at data_step 5 on rank 1 and
    // restarts once on the surviving 1M1G world
    let final_a = outdir.path().join("final_a.bckp");
    let mut a = train_args("1M2G");
    a.extend(["--save-every".into(), "2".into(),
              "--keep-last".into(), "3".into(),
              "--ckpt-dir".into(), rot_a.path().to_str().unwrap().into(),
              "--inject-fail".into(), "5:1".into(),
              "--max-restarts".into(), "1".into(),
              "--restart-topo".into(), "1M1G".into(),
              "--ckpt".into(), final_a.to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&a)
        .output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(),
            "stdout:\n{stdout}\nstderr:\n{stderr}");
    assert!(stderr.contains("training attempt 1 failed"), "{stderr}");
    assert!(stderr.contains("injected failure"), "{stderr}");
    assert!(stderr.contains("rank 1"), "{stderr}");
    // progress lost <= --save-every: the relaunch resumes at data_step
    // 4, the last verified rotation boundary before the fault at 5
    assert!(stdout.contains("restart 1: relaunching on 1M1G from \
                             data_step 4"),
            "{stdout}");
    assert!(stdout.contains("resuming reshaped"), "{stdout}");
    assert!(stdout.contains("phase 1 done"), "{stdout}");

    // baseline B: a CLEAN 6-step 1M2G run with the same rotation plan
    // (its ckpt-4 is bitwise the same boundary run A restarted from),
    // then a manual reshaped restart of that boundary on 1M1G
    let mut b1 = train_args("1M2G");
    b1.extend(["--save-every".into(), "2".into(),
               "--keep-last".into(), "3".into(),
               "--ckpt-dir".into(),
               rot_b.path().to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&b1)
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));
    let boundary = rot_b.path().join(checkpoint::checkpoint_file_name(4));
    let final_b = outdir.path().join("final_b.bckp");
    let mut b2 = train_args("1M1G");
    b2.extend(["--resume-reshape".into(),
               boundary.to_str().unwrap().into(),
               "--ckpt".into(), final_b.to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&b2)
        .output().unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(),
            "stdout:\n{stdout}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stderr));
    assert!(stdout.contains("resuming reshaped"), "{stdout}");

    let ca = Checkpoint::load(&final_a).unwrap();
    let cb = Checkpoint::load(&final_b).unwrap();
    assert_eq!(ca.step, 6);
    assert_eq!(ca, cb,
               "elastic restart and a clean reshaped resume from the \
                same boundary must converge bitwise");
}

#[test]
fn train_save_every_requires_ckpt_dir() {
    let out = bin()
        .args(["train", "--save-every", "2"])
        .output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--ckpt-dir"));
}

#[test]
fn train_ckpt_dir_without_save_every_is_rejected_not_inert() {
    let dir = bertdist::testkit::tmp_ckpt_dir("cli_inert");
    let out = bin()
        .args(["train", "--ckpt-dir", dir.path().to_str().unwrap()])
        .output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr)
                .contains("--save-every"));
}

#[test]
fn train_save_every_resume_round_trip() {
    // run with periodic rotated checkpoints, then resume exactly from
    // the rotation dir and check the reported starting step/loss scale
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use bertdist::checkpoint;
    let data = bertdist::testkit::tmp_dir("cli_rt_data");
    let ckdir = bertdist::testkit::tmp_ckpt_dir("cli_rt");
    let out = bin()
        .args(["shard-data", "--out", data.path().to_str().unwrap(),
               "--docs", "12", "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let train_args = |steps: &str| {
        vec!["train".to_string(), "--preset".into(), "bert-micro".into(),
             "--topo".into(), "1M2G".into(), "--steps".into(), steps.into(),
             "--accum".into(), "1".into(), "--batch".into(), "2".into(),
             "--seq".into(), "32".into(), "--lr".into(), "1e-3".into(),
             "--log-every".into(), "0".into(),
             "--data-dir".into(), data.path().to_str().unwrap().into()]
    };
    let mut first = train_args("4");
    first.extend(["--ckpt-dir".into(),
                  ckdir.path().to_str().unwrap().into(),
                  "--save-every".into(), "2".into(), "--keep-last".into(),
                  "2".into()]);
    let out = bin()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(&first)
        .output().unwrap();
    assert!(out.status.success(),
            "stdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout)
                .contains("async checkpoints: 2 files"));

    // rotation: exactly the two newest boundaries survive
    let files = checkpoint::list_checkpoints(ckdir.path()).unwrap();
    let steps: Vec<u64> = files.iter().map(|(s, _)| *s).collect();
    assert_eq!(steps, vec![2, 4]);
    let latest = checkpoint::latest_checkpoint(ckdir.path())
        .unwrap().unwrap();
    let ck = checkpoint::Checkpoint::load(&latest).unwrap();
    assert_eq!(ck.step, 4);

    // exact resume from the rotation dir toward a 6-step target: the
    // reported starting step/scale must match the checkpoint on disk,
    // and only the REMAINING steps run (completed ones are subtracted)
    let mut second = train_args("6");
    second.extend(["--resume".into(),
                   ckdir.path().to_str().unwrap().into()]);
    let out = bin()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(&second)
        .output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(),
            "stdout:\n{text}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("resume checkpoint"), "{text}");
    assert!(text.contains(&format!("step {}, data_step {}, loss scale {}",
                                   ck.step, ck.data_step,
                                   ck.loss_scale())),
            "{text}");
    assert!(text.contains("resuming exactly"), "{text}");
    assert!(text.contains("4/6 phase-1 steps already done — running 2 \
                           more"),
            "{text}");
    assert!(text.contains("steps=2"), "only the remaining steps ran: \
                                       {text}");
}

#[test]
fn info_lists_artifacts() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let out = bin()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .arg("info")
        .output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("bert-micro"));
    assert!(text.contains("apply_lamb"));
}

/// Base `train` arguments shared by the socket-transport tests: a tiny
/// deterministic run whose results a second process must reproduce.
#[cfg(unix)]
fn socket_train_args(topo: &str, steps: &str, data: &std::path::Path)
    -> Vec<String> {
    vec!["train".to_string(), "--preset".into(), "bert-micro".into(),
         "--topo".into(), topo.into(), "--steps".into(), steps.into(),
         "--accum".into(), "1".into(), "--batch".into(), "2".into(),
         "--seq".into(), "32".into(), "--lr".into(), "1e-3".into(),
         "--log-every".into(), "0".into(),
         "--data-dir".into(), data.to_str().unwrap().into()]
}

#[cfg(unix)]
fn spawn_train(args: &[String]) -> std::process::Child {
    use std::process::Stdio;
    bin().current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(args)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn train process")
}

#[cfg(unix)]
#[test]
fn train_two_process_socket_run_matches_inproc_bitwise() {
    // the transport acceptance criterion: the SAME 1M2G config run as
    // two real processes over loopback unix sockets (one rank each,
    // --listen/--connect) must finish with final parameters bitwise
    // identical to the single-process in-memory run.  The transport is
    // allowed to change WHERE ranks live, never what they compute.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use bertdist::checkpoint::Checkpoint;
    let data = bertdist::testkit::tmp_dir("cli_sock_data");
    let outdir = bertdist::testkit::tmp_dir("cli_sock_out");
    let out = bin()
        .args(["shard-data", "--out", data.path().to_str().unwrap(),
               "--docs", "12", "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let socks: Vec<String> = (0..2)
        .map(|i| format!("unix:{}/p{i}.sock",
                         outdir.path().to_str().unwrap()))
        .collect();
    let connect = socks.join(",");
    let final_sock = outdir.path().join("final_sock.bckp");
    let base = socket_train_args("1M2G", "4", data.path());

    // process 0 hosts rank 0 (first --connect entry) and is the lead:
    // it alone writes the final checkpoint
    let mut a = base.clone();
    a.extend(["--listen".into(), socks[0].clone(),
              "--connect".into(), connect.clone(),
              "--ckpt".into(), final_sock.to_str().unwrap().into()]);
    let mut b = base.clone();
    b.extend(["--listen".into(), socks[1].clone(),
              "--connect".into(), connect]);
    let pa = spawn_train(&a);
    let pb = spawn_train(&b);
    let oa = pa.wait_with_output().unwrap();
    let ob = pb.wait_with_output().unwrap();
    let (sa, ea) = (String::from_utf8_lossy(&oa.stdout),
                    String::from_utf8_lossy(&oa.stderr));
    let (sb, eb) = (String::from_utf8_lossy(&ob.stdout),
                    String::from_utf8_lossy(&ob.stderr));
    assert!(oa.status.success(), "proc 0 stdout:\n{sa}\nstderr:\n{ea}");
    assert!(ob.status.success(), "proc 1 stdout:\n{sb}\nstderr:\n{eb}");
    // each process hosts its contiguous slice of the world
    assert!(sa.contains("ranks=0..1"), "{sa}");
    assert!(sb.contains("ranks=1..2"), "{sb}");
    assert!(sa.contains("phase 1 done"), "{sa}");

    // the same config, single process, in-memory transport
    let final_in = outdir.path().join("final_inproc.bckp");
    let mut c = base;
    c.extend(["--ckpt".into(), final_in.to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&c)
        .output().unwrap();
    assert!(out.status.success(),
            "stdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr));

    let ck_sock = Checkpoint::load(&final_sock).unwrap();
    let ck_in = Checkpoint::load(&final_in).unwrap();
    assert_eq!(ck_sock.step, 4);
    assert_eq!(ck_sock, ck_in,
               "a 2-process socket run must be bitwise identical to the \
                single-process in-memory run");
}

#[cfg(unix)]
#[test]
fn train_socket_peer_loss_restarts_single_process_and_matches_clean_run() {
    // the elasticity contract over REAL process loss: a 2-process
    // socket run loses its peer (rank 1's process dies mid-step), the
    // survivor's --max-restarts drops the socket transport, relaunches
    // single-process on the surviving --restart-topo world from the
    // newest verified rotation checkpoint, and its final parameters are
    // bitwise-equal to a clean reshaped resume from the same boundary.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use bertdist::checkpoint::{self, Checkpoint};
    let data = bertdist::testkit::tmp_dir("cli_sock_elastic_data");
    let rot_a = bertdist::testkit::tmp_ckpt_dir("cli_sock_elastic_rot_a");
    let rot_b = bertdist::testkit::tmp_ckpt_dir("cli_sock_elastic_rot_b");
    let outdir = bertdist::testkit::tmp_dir("cli_sock_elastic_out");
    let out = bin()
        .args(["shard-data", "--out", data.path().to_str().unwrap(),
               "--docs", "12", "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let socks: Vec<String> = (0..2)
        .map(|i| format!("unix:{}/p{i}.sock",
                         outdir.path().to_str().unwrap()))
        .collect();
    let connect = socks.join(",");
    let base = socket_train_args("1M2G", "6", data.path());

    // survivor: lead process hosting rank 0, supervised with one
    // restart onto the shrunken 1M1G world
    let final_a = outdir.path().join("final_a.bckp");
    let mut a = base.clone();
    a.extend(["--listen".into(), socks[0].clone(),
              "--connect".into(), connect.clone(),
              "--net-timeout".into(), "20".into(),
              "--save-every".into(), "2".into(),
              "--keep-last".into(), "3".into(),
              "--ckpt-dir".into(), rot_a.path().to_str().unwrap().into(),
              "--max-restarts".into(), "1".into(),
              "--restart-topo".into(), "1M1G".into(),
              "--ckpt".into(), final_a.to_str().unwrap().into()]);
    // doomed peer: hosts rank 1 and dies deterministically at
    // data_step 5 — from the survivor's side this is a real process
    // loss (sockets close mid-exchange), not an in-process unwind
    let mut b = base.clone();
    b.extend(["--listen".into(), socks[1].clone(),
              "--connect".into(), connect,
              "--net-timeout".into(), "20".into(),
              "--inject-fail".into(), "5:1".into()]);
    let pa = spawn_train(&a);
    let pb = spawn_train(&b);
    let ob = pb.wait_with_output().unwrap();
    let oa = pa.wait_with_output().unwrap();
    let (sb, eb) = (String::from_utf8_lossy(&ob.stdout),
                    String::from_utf8_lossy(&ob.stderr));
    assert!(!ob.status.success(),
            "the doomed peer must die: stdout:\n{sb}\nstderr:\n{eb}");
    assert!(eb.contains("injected failure"), "{eb}");
    let (sa, ea) = (String::from_utf8_lossy(&oa.stdout),
                    String::from_utf8_lossy(&oa.stderr));
    assert!(oa.status.success(),
            "survivor stdout:\n{sa}\nstderr:\n{ea}");
    assert!(ea.contains("training attempt 1 failed"), "{ea}");
    assert!(ea.contains("pooled step 5 failed"), "{ea}");
    // the relaunch leaves the dead peer's sockets behind and resumes at
    // the last verified rotation boundary before the fault
    assert!(sa.contains("restart: dropping the socket transport"),
            "{sa}");
    assert!(sa.contains("restart 1: relaunching on 1M1G from data_step 4"),
            "{sa}");
    assert!(sa.contains("resuming reshaped"), "{sa}");
    assert!(sa.contains("phase 1 done"), "{sa}");

    // baseline: a CLEAN single-process 1M2G run with the same rotation
    // plan, then a manual reshaped restart of its step-4 boundary —
    // exactly the state the survivor reconstructed the hard way
    let mut b1 = base.clone();
    b1.extend(["--save-every".into(), "2".into(),
               "--keep-last".into(), "3".into(),
               "--ckpt-dir".into(),
               rot_b.path().to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&b1)
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));
    let boundary = rot_b.path().join(checkpoint::checkpoint_file_name(4));
    let final_b = outdir.path().join("final_b.bckp");
    let mut b2 = socket_train_args("1M1G", "6", data.path());
    b2.extend(["--resume-reshape".into(),
               boundary.to_str().unwrap().into(),
               "--ckpt".into(), final_b.to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&b2)
        .output().unwrap();
    assert!(out.status.success(),
            "stdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr));

    let ca = Checkpoint::load(&final_a).unwrap();
    let cb = Checkpoint::load(&final_b).unwrap();
    assert_eq!(ca.step, 6);
    assert_eq!(ca, cb,
               "surviving a real peer loss and a clean reshaped resume \
                from the same boundary must converge bitwise");
}

/// Poll `file` until it holds exactly `n` non-empty lines (rendezvous
/// rank order is append order, so tests serialize joins to pin which
/// process becomes rank 0 / the lead).
#[cfg(unix)]
fn wait_for_rendezvous_lines(file: &std::path::Path, n: usize) {
    let deadline = std::time::Instant::now()
        + std::time::Duration::from_secs(30);
    loop {
        let lines = std::fs::read_to_string(file)
            .map(|t| t.lines().filter(|l| !l.trim().is_empty()).count())
            .unwrap_or(0);
        if lines == n {
            return;
        }
        assert!(std::time::Instant::now() < deadline,
                "rendezvous file {} never reached {n} line(s)",
                file.display());
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}

#[cfg(unix)]
#[test]
fn train_socket_peer_loss_rejoins_at_same_size_and_matches_clean_run() {
    // the ISSUE-8 grow-back contract: a 2-process rendezvous run loses
    // rank 1's process to a cut link mid-exchange; within
    // --rejoin-window the supervisor republishes the rendezvous at
    // epoch 1 instead of shrinking, a REPLACEMENT process joins at the
    // SAME world size from the shared rotation checkpoint, and the
    // final parameters are bitwise-equal to a clean resume of that
    // boundary on the same topology.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use std::io::BufRead;
    use bertdist::checkpoint::Checkpoint;
    let data = bertdist::testkit::tmp_dir("cli_rejoin_data");
    let rot_a = bertdist::testkit::tmp_ckpt_dir("cli_rejoin_rot_a");
    let rot_b = bertdist::testkit::tmp_ckpt_dir("cli_rejoin_rot_b");
    let outdir = bertdist::testkit::tmp_dir("cli_rejoin_out");
    let out = bin()
        .args(["shard-data", "--out", data.path().to_str().unwrap(),
               "--docs", "12", "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let rdv = outdir.path().join("rdv.txt");
    let rdv_s = rdv.to_str().unwrap().to_string();
    let sock = |i: usize| {
        format!("unix:{}/p{i}.sock", outdir.path().to_str().unwrap())
    };
    let base = socket_train_args("1M2G", "6", data.path());

    // survivor: lead process (joins first => rank 0), supervised with
    // one restart and a 20 s grow-back window
    let final_a = outdir.path().join("final_a.bckp");
    let mut a = base.clone();
    a.extend(["--listen".into(), sock(0),
              "--rendezvous".into(), rdv_s.clone(),
              "--nprocs".into(), "2".into(),
              "--net-timeout".into(), "20".into(),
              "--save-every".into(), "2".into(),
              "--keep-last".into(), "3".into(),
              "--ckpt-dir".into(), rot_a.path().to_str().unwrap().into(),
              "--max-restarts".into(), "1".into(),
              "--rejoin-window".into(), "20".into(),
              "--ckpt".into(), final_a.to_str().unwrap().into()]);
    let mut pa = spawn_train(&a);
    wait_for_rendezvous_lines(&rdv, 1);

    // doomed peer: its socket links are CUT at data_step 5 (a real
    // process loss from the survivor's side), and with no restarts of
    // its own it dies
    let mut b = base.clone();
    b.extend(["--listen".into(), sock(1),
              "--rendezvous".into(), rdv_s.clone(),
              "--nprocs".into(), "2".into(),
              "--net-timeout".into(), "20".into(),
              "--inject-fail".into(), "net:5".into()]);
    let pb = spawn_train(&b);
    let ob = pb.wait_with_output().unwrap();
    assert!(!ob.status.success(),
            "the doomed peer must die: stdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&ob.stdout),
            String::from_utf8_lossy(&ob.stderr));
    assert!(String::from_utf8_lossy(&ob.stderr)
                .contains("injected network fault"),
            "{}", String::from_utf8_lossy(&ob.stderr));

    // watch the survivor's stdout for the republished epoch, THEN
    // launch the replacement — it adopts generation 1 from the stamp
    // and restores the same rotation boundary the survivor picked
    let mut sa_lines: Vec<String> = Vec::new();
    let mut reader = std::io::BufReader::new(
        pa.stdout.take().expect("survivor stdout piped"));
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).unwrap();
        assert!(n > 0,
                "survivor exited before republishing: {}",
                sa_lines.join("\n"));
        sa_lines.push(line.trim_end().to_string());
        if sa_lines.last().unwrap()
            .contains("rejoin: republished rendezvous epoch 1") {
            break;
        }
    }
    wait_for_rendezvous_lines(&rdv, 1); // survivor re-registered first
    let mut c = base.clone();
    c.extend(["--listen".into(), sock(2),
              "--rendezvous".into(), rdv_s.clone(),
              "--nprocs".into(), "2".into(),
              "--net-timeout".into(), "20".into(),
              "--resume".into(), rot_a.path().to_str().unwrap().into()]);
    let pc = spawn_train(&c);

    for line in reader.lines() {
        sa_lines.push(line.unwrap());
    }
    let status_a = pa.wait().unwrap();
    let oc = pc.wait_with_output().unwrap();
    let sa = sa_lines.join("\n");
    let sc = String::from_utf8_lossy(&oc.stdout);
    assert!(status_a.success(), "survivor stdout:\n{sa}");
    assert!(oc.status.success(),
            "replacement stdout:\n{sc}\nstderr:\n{}",
            String::from_utf8_lossy(&oc.stderr));
    // the grow-back kept the world size: same topology, boundary 4
    assert!(sa.contains("restart 1: relaunching on 1M2G from data_step 4"),
            "{sa}");
    assert!(sa.contains("phase 1 done"), "{sa}");
    assert!(sc.contains("resume checkpoint"), "{sc}");
    assert!(sc.contains("4/6 phase-1 steps already done"), "{sc}");

    // baseline: a clean 1M2G run with the same rotation plan, then an
    // exact resume of its step-4 boundary — the state the survivor and
    // replacement reconstructed across the rejoin
    let mut b1 = base.clone();
    b1.extend(["--save-every".into(), "2".into(),
               "--keep-last".into(), "3".into(),
               "--ckpt-dir".into(),
               rot_b.path().to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&b1)
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));
    let final_b = outdir.path().join("final_b.bckp");
    let mut b2 = base.clone();
    b2.extend(["--resume".into(), rot_b.path().to_str().unwrap().into(),
               "--ckpt".into(), final_b.to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&b2)
        .output().unwrap();
    assert!(out.status.success(),
            "stdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr));

    let ca = Checkpoint::load(&final_a).unwrap();
    let cb = Checkpoint::load(&final_b).unwrap();
    assert_eq!(ca.step, 6);
    assert_eq!(ca, cb,
               "a grow-back rejoin and a clean exact resume from the \
                same boundary must converge bitwise");
}

#[cfg(unix)]
#[test]
fn train_rejoin_window_expiry_degrades_to_shrink_restart() {
    // when nobody rejoins inside --rejoin-window, the supervisor must
    // not hang: the expired window surfaces as a setup failure, the
    // NEXT restart drops the socket transport, and the run finishes
    // shrunken on --restart-topo — bitwise equal to a clean reshaped
    // resume of the same boundary.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    use bertdist::checkpoint::{self, Checkpoint};
    let data = bertdist::testkit::tmp_dir("cli_rejoin_exp_data");
    let rot_a = bertdist::testkit::tmp_ckpt_dir("cli_rejoin_exp_rot_a");
    let rot_b = bertdist::testkit::tmp_ckpt_dir("cli_rejoin_exp_rot_b");
    let outdir = bertdist::testkit::tmp_dir("cli_rejoin_exp_out");
    let out = bin()
        .args(["shard-data", "--out", data.path().to_str().unwrap(),
               "--docs", "12", "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let rdv = outdir.path().join("rdv.txt");
    let rdv_s = rdv.to_str().unwrap().to_string();
    let sock = |i: usize| {
        format!("unix:{}/p{i}.sock", outdir.path().to_str().unwrap())
    };
    let base = socket_train_args("1M2G", "6", data.path());

    // survivor: two restarts — the first burns the 2 s rejoin window
    // (no replacement will come), the second shrinks to 1M1G
    let final_a = outdir.path().join("final_a.bckp");
    let mut a = base.clone();
    a.extend(["--listen".into(), sock(0),
              "--rendezvous".into(), rdv_s.clone(),
              "--nprocs".into(), "2".into(),
              "--net-timeout".into(), "20".into(),
              "--save-every".into(), "2".into(),
              "--keep-last".into(), "3".into(),
              "--ckpt-dir".into(), rot_a.path().to_str().unwrap().into(),
              "--max-restarts".into(), "2".into(),
              "--rejoin-window".into(), "2".into(),
              "--restart-topo".into(), "1M1G".into(),
              "--ckpt".into(), final_a.to_str().unwrap().into()]);
    let pa = spawn_train(&a);
    wait_for_rendezvous_lines(&rdv, 1);
    let mut b = base.clone();
    b.extend(["--listen".into(), sock(1),
              "--rendezvous".into(), rdv_s.clone(),
              "--nprocs".into(), "2".into(),
              "--net-timeout".into(), "20".into(),
              "--inject-fail".into(), "net:5".into()]);
    let pb = spawn_train(&b);
    let ob = pb.wait_with_output().unwrap();
    assert!(!ob.status.success(),
            "the doomed peer must die: {}",
            String::from_utf8_lossy(&ob.stderr));
    let oa = pa.wait_with_output().unwrap();
    let (sa, ea) = (String::from_utf8_lossy(&oa.stdout),
                    String::from_utf8_lossy(&oa.stderr));
    assert!(oa.status.success(), "survivor stdout:\n{sa}\nstderr:\n{ea}");
    // restart 1: grow-back attempted at the same size...
    assert!(sa.contains("rejoin: republished rendezvous epoch 1"), "{sa}");
    assert!(sa.contains("restart 1: relaunching on 1M2G from data_step 4"),
            "{sa}");
    // ...which expires (nobody rejoined) and degrades to the shrink
    assert!(ea.contains("rejoin window expired"), "{ea}");
    assert!(sa.contains("restart: dropping the socket transport"), "{sa}");
    assert!(sa.contains("restart 2: relaunching on 1M1G from data_step 4"),
            "{sa}");
    assert!(sa.contains("phase 1 done"), "{sa}");

    // baseline: clean rotation run, then a manual reshaped restart of
    // the step-4 boundary on the surviving 1M1G world
    let mut b1 = base.clone();
    b1.extend(["--save-every".into(), "2".into(),
               "--keep-last".into(), "3".into(),
               "--ckpt-dir".into(),
               rot_b.path().to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&b1)
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));
    let boundary = rot_b.path().join(checkpoint::checkpoint_file_name(4));
    let final_b = outdir.path().join("final_b.bckp");
    let mut b2 = socket_train_args("1M1G", "6", data.path());
    b2.extend(["--resume-reshape".into(),
               boundary.to_str().unwrap().into(),
               "--ckpt".into(), final_b.to_str().unwrap().into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&b2)
        .output().unwrap();
    assert!(out.status.success(),
            "stdout:\n{}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr));

    let ca = Checkpoint::load(&final_a).unwrap();
    let cb = Checkpoint::load(&final_b).unwrap();
    assert_eq!(ca.step, 6);
    assert_eq!(ca, cb,
               "an expired rejoin window must fall back to the same \
                state as a clean reshaped resume");
}

#[cfg(unix)]
#[test]
fn train_wrong_net_key_is_rejected_loudly() {
    // two processes with DIFFERENT --net-key must refuse to form a
    // world: the accept side names the MAC mismatch and both exit
    // nonzero, long before any gradient crosses the wire.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let data = bertdist::testkit::tmp_dir("cli_badkey_data");
    let outdir = bertdist::testkit::tmp_dir("cli_badkey_out");
    let out = bin()
        .args(["shard-data", "--out", data.path().to_str().unwrap(),
               "--docs", "12", "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let socks: Vec<String> = (0..2)
        .map(|i| format!("unix:{}/p{i}.sock",
                         outdir.path().to_str().unwrap()))
        .collect();
    let connect = socks.join(",");
    let base = socket_train_args("1M2G", "1", data.path());
    let mut a = base.clone();
    a.extend(["--listen".into(), socks[0].clone(),
              "--connect".into(), connect.clone(),
              "--net-timeout".into(), "5".into(),
              "--net-key".into(), "right-key".into()]);
    let mut b = base;
    b.extend(["--listen".into(), socks[1].clone(),
              "--connect".into(), connect,
              "--net-timeout".into(), "5".into(),
              "--net-key".into(), "wrong-key".into()]);
    let pa = spawn_train(&a);
    let pb = spawn_train(&b);
    let oa = pa.wait_with_output().unwrap();
    let ob = pb.wait_with_output().unwrap();
    assert!(!oa.status.success() && !ob.status.success(),
            "mismatched keys must fail both processes");
    let errs = format!("{}{}",
                       String::from_utf8_lossy(&oa.stderr),
                       String::from_utf8_lossy(&ob.stderr));
    assert!(errs.contains("MAC mismatch"), "{errs}");
}

#[cfg(unix)]
#[test]
fn train_stale_rendezvous_file_exits_with_its_own_code() {
    // a rendezvous file stamped by a DIFFERENT run must be refused
    // with the dedicated taxonomy exit (6), never silently adopted —
    // and never retried by the supervisor.
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let data = bertdist::testkit::tmp_dir("cli_stale_data");
    let outdir = bertdist::testkit::tmp_dir("cli_stale_out");
    let out = bin()
        .args(["shard-data", "--out", data.path().to_str().unwrap(),
               "--docs", "12", "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let rdv = outdir.path().join("rdv.txt");
    let rdv_s = rdv.to_str().unwrap().to_string();
    // stamp the file as a foreign run's, generation 0
    bertdist::collectives::socket::write_stamp(&rdv_s, [0xAA; 8], 0)
        .unwrap();
    let mut a = socket_train_args("1M2G", "1", data.path());
    a.extend(["--listen".into(),
              format!("unix:{}/p0.sock", outdir.path().to_str().unwrap()),
              "--rendezvous".into(), rdv_s,
              "--nprocs".into(), "2".into(),
              "--net-timeout".into(), "5".into()]);
    let out = bin().current_dir(env!("CARGO_MANIFEST_DIR")).args(&a)
        .output().unwrap();
    assert_eq!(out.status.code(), Some(6),
               "stderr:\n{}", String::from_utf8_lossy(&out.stderr));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("stale rendezvous"), "{err}");
    assert!(err.contains("different run"), "{err}");
}

#[test]
fn train_rejects_oversized_vocab() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let dir = std::env::temp_dir().join("bertdist_cli_badvocab");
    let _ = std::fs::remove_dir_all(&dir);
    bin().args(["shard-data", "--out", dir.to_str().unwrap(), "--docs",
                "12", "--shards", "2", "--vocab-size", "4096"])
        .output().unwrap();
    let out = bin()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(["train", "--preset", "bert-micro", "--steps", "1",
               "--batch", "2", "--seq", "32",
               "--data-dir", dir.to_str().unwrap()])
        .output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("vocab"));
    let _ = std::fs::remove_dir_all(&dir);
}
