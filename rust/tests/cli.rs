//! CLI smoke tests: drive the real `bertdist` binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_bertdist"))
}

fn have_artifacts() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

#[test]
fn no_args_prints_usage() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("USAGE"));
    assert!(text.contains("shard-data"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert_eq!(out.status.code(), Some(2));
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn unknown_option_is_rejected() {
    let out = bin().args(["cost", "--dayz", "3"]).output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("--dayz"));
}

#[test]
fn cost_command_prints_paper_tables() {
    let out = bin().arg("cost").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("$624000"));
    assert!(text.contains("$4768000"));
    assert!(text.contains("25804.8"));
}

#[test]
fn scaling_command_reports_headline() {
    let out = bin().args(["scaling", "--mode", "multinode"]).output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("32M8G"));
    assert!(text.contains("headline"));
}

#[test]
fn simulate_command_renders_timeline() {
    let out = bin()
        .args(["simulate", "--topo", "2M1G", "--accum", "2",
               "--print-topology"])
        .output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("compute utilization"));
    assert!(text.contains("Node 0"));
    assert!(text.contains("gpu"));
}

#[test]
fn profile_grads_matches_figure4() {
    let out = bin().args(["profile-grads", "--preset", "bert-large"])
        .output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("attention"));
    assert!(text.contains("dense"));
}

#[test]
fn profile_grads_emits_exchange_trace() {
    // The --trace path runs REAL pooled steps (no XLA artifacts needed)
    // and writes PCIe/network chrome-trace spans.
    let path = std::env::temp_dir().join("bertdist_cli_exchange.json");
    let _ = std::fs::remove_file(&path);
    let out = bin()
        .args(["profile-grads", "--preset", "bert-micro", "--trace",
               path.to_str().unwrap(), "--topology", "2M2G", "--comm-mode",
               "hierarchical", "--steps", "2", "--accum", "1",
               "--bucket-elems", "65536"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("exchange profile"));
    assert!(text.contains("hierarchical"));
    let trace = std::fs::read_to_string(&path).unwrap();
    assert!(trace.contains("traceEvents"));
    assert!(trace.contains("pcie") && trace.contains("net"), "{trace}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn bad_comm_mode_is_rejected() {
    let out = bin()
        .args(["profile-grads", "--preset", "bert-micro", "--comm-mode",
               "rings"])
        .output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("comm-mode"));
}

#[test]
fn amp_demo_runs() {
    let out = bin().args(["amp-demo", "--steps", "50"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fp16"));
    assert!(text.contains("scale"));
}

#[test]
fn shard_then_train_roundtrip() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let dir = std::env::temp_dir().join("bertdist_cli_train");
    let _ = std::fs::remove_dir_all(&dir);
    let out = bin()
        .args(["shard-data", "--out", dir.to_str().unwrap(), "--docs", "12",
               "--shards", "2", "--vocab-size", "512"])
        .output().unwrap();
    assert!(out.status.success(),
            "{}", String::from_utf8_lossy(&out.stderr));

    let out = bin()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(["train", "--preset", "bert-micro", "--topo", "1M2G",
               "--steps", "4", "--accum", "1", "--batch", "2", "--seq",
               "32", "--data-dir", dir.to_str().unwrap(), "--log-every",
               "2", "--lr", "1e-3"])
        .output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(),
            "stdout:\n{text}\nstderr:\n{}",
            String::from_utf8_lossy(&out.stderr));
    assert!(text.contains("phase 1 done"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn info_lists_artifacts() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let out = bin()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .arg("info")
        .output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("bert-micro"));
    assert!(text.contains("apply_lamb"));
}

#[test]
fn train_rejects_oversized_vocab() {
    if !have_artifacts() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let dir = std::env::temp_dir().join("bertdist_cli_badvocab");
    let _ = std::fs::remove_dir_all(&dir);
    bin().args(["shard-data", "--out", dir.to_str().unwrap(), "--docs",
                "12", "--shards", "2", "--vocab-size", "4096"])
        .output().unwrap();
    let out = bin()
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .args(["train", "--preset", "bert-micro", "--steps", "1",
               "--batch", "2", "--seq", "32",
               "--data-dir", dir.to_str().unwrap()])
        .output().unwrap();
    assert_eq!(out.status.code(), Some(1));
    assert!(String::from_utf8_lossy(&out.stderr).contains("vocab"));
    let _ = std::fs::remove_dir_all(&dir);
}
