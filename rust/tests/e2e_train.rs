//! Integration: the full training stack against real AOT artifacts.
//! Requires `make artifacts` (skips gracefully otherwise — CI runs
//! `make test` which guarantees artifacts exist).

use std::path::{Path, PathBuf};

use bertdist::config::RunConfig;
use bertdist::coordinator::{prepare_datasets, train_run};
use bertdist::data::corpus::SyntheticCorpus;
use bertdist::data::{build_shards, Vocab};
use bertdist::runtime::Engine;
use bertdist::topology::Topology;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn make_data(dir: &Path, vocab_size: usize, shards: usize) {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).unwrap();
    let docs = SyntheticCorpus::new(9, 2_000).documents(24, 8, 10);
    let vocab = Vocab::from_documents(&docs, vocab_size);
    vocab.save(&dir.join("vocab.txt")).unwrap();
    build_shards(&docs, &vocab, shards, dir, "train", 9).unwrap();
}

fn base_cfg(topo: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.train.preset = "bert-micro".into();
    cfg.train.variant = "fused_f32".into();
    cfg.train.lr = 1e-3;
    cfg.train.warmup_steps = 2;
    cfg.train.accum_steps = 2;
    cfg.train.log_every = 0;
    cfg.cluster.topo = Topology::parse(topo).unwrap();
    cfg
}

#[test]
fn training_reduces_loss_end_to_end() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dir = std::env::temp_dir().join("bertdist_it_train");
    make_data(&dir, 512, 2);
    let engine = Engine::cpu(&art).unwrap();
    let cfg = base_cfg("1M2G");
    let out = train_run(&engine, &cfg, &dir, 25, 0, 2, 32, None).unwrap();
    let r = &out.phase1;
    assert_eq!(r.steps, 25);
    let head = r.loss.points[0].1;
    let tail = r.loss.tail_mean(5);
    assert!(tail < head, "loss did not improve: {head} -> {tail}");
    assert!(tail.is_finite());
    assert_eq!(r.skipped_steps, 0, "no overflow expected in f32");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn world_sizes_agree_on_sync_semantics() {
    // Data-parallel invariant: with the SAME total micro-batches, the
    // averaged gradient magnitude (and thus training) is stable across
    // topologies; here we check 1M1G and 1M2G both learn and produce
    // finite params.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    for topo in ["1M1G", "1M2G", "2M2G"] {
        let dir = std::env::temp_dir()
            .join(format!("bertdist_it_world_{topo}"));
        make_data(&dir, 512, 4);
        let cfg = base_cfg(topo);
        let out = train_run(&engine, &cfg, &dir, 6, 0, 2, 32, None).unwrap();
        assert!(out.phase1.loss.tail_mean(3).is_finite(), "{topo}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn two_phase_schedule_runs_seq512() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    // bert-micro has no phase-2 artifact (max_seq 64); bert-tiny does.
    let dir = std::env::temp_dir().join("bertdist_it_phase2");
    make_data(&dir, 8192, 2);
    let engine = Engine::cpu(&art).unwrap();
    let mut cfg = base_cfg("1M1G");
    cfg.train.preset = "bert-tiny".into();
    cfg.train.accum_steps = 1;
    let out = train_run(&engine, &cfg, &dir, 3, 2, 8, 128, None).unwrap();
    let r2 = out.phase2.expect("phase 2 must run");
    assert_eq!(r2.steps, 2);
    assert!(r2.loss.tail_mean(2).is_finite());
    // phase-2 starts from phase-1 weights: its loss should not be at
    // random-init level + margin (ln(8192)+ln2 ~ 9.7)
    assert!(r2.loss.points[0].1 < 11.0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn overlap_modes_produce_identical_params() {
    // Acceptance (ISSUE 1): the eager Fig. 2 schedule and the barrier
    // schedule must train to BITWISE-identical parameters — overlap
    // changes when buckets are exchanged, never what is computed.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dir = std::env::temp_dir().join("bertdist_it_overlap");
    make_data(&dir, 512, 4);
    let engine = Engine::cpu(&art).unwrap();
    let datasets = prepare_datasets(&dir, 2).unwrap();
    let mut finals: Vec<Vec<f32>> = Vec::new();
    for overlap in [true, false] {
        let mut cfg = base_cfg("1M2G");
        cfg.train.overlap = overlap;
        let mut t = bertdist::trainer::Trainer::new(&engine, cfg, 32, 2)
            .unwrap();
        let r = t.run(&datasets, 8, 8).unwrap();
        assert_eq!(r.steps, 8);
        finals.push(t.params.clone());
    }
    assert_eq!(finals[0].len(), finals[1].len());
    for (i, (a, b)) in finals[0].iter().zip(finals[1].iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(),
                   "param [{i}] diverged between overlap modes: {a} vs {b}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn f16_wire_training_converges() {
    // §4.4 FP16 gradient exchange: quantized wire, same training story.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dir = std::env::temp_dir().join("bertdist_it_wire16");
    make_data(&dir, 512, 2);
    let engine = Engine::cpu(&art).unwrap();
    let mut cfg = base_cfg("1M2G");
    cfg.train.grad_wire_f16 = true;
    let out = train_run(&engine, &cfg, &dir, 20, 0, 2, 32, None).unwrap();
    let r = &out.phase1;
    assert!(r.loss.tail_mean(5).is_finite());
    assert!(r.loss.tail_mean(5) < r.loss.points[0].1,
            "f16-wire training did not improve");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_resume_is_exact() {
    // (The deep resume-equivalence property sweep lives in
    // checkpoint_resume.rs; this is the train_run-level smoke.)
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let dir = bertdist::testkit::tmp_dir("it_ckpt");
    make_data(dir.path(), 512, 2);
    let engine = Engine::cpu(&art).unwrap();
    let cfg = base_cfg("1M2G");
    let ckdir = bertdist::testkit::tmp_ckpt_dir("it_ckpt");
    let ck = ckdir.join("t.ckpt");

    // run 6 steps with a checkpoint at step 6
    let out_a = train_run(&engine, &cfg, dir.path(), 6, 0, 2, 32, Some(&ck))
        .unwrap();
    assert!(ck.exists());
    // the saved state is a v2 checkpoint with the full stream position
    let ckpt = bertdist::checkpoint::Checkpoint::load(&ck).unwrap();
    assert_eq!(ckpt.step as usize, out_a.trainer_step);
    assert!(ckpt.exact_data_position);
    assert_eq!(ckpt.data_step, 6, "no skips: data_step == attempted steps");
    assert!(ckpt.fingerprint.is_some());
    assert_eq!(ckpt.scaler.total_steps, 6);
    assert!(ckpt.params.iter().all(|p| p.is_finite()));
}

#[test]
fn variant_artifacts_agree_on_forward_loss() {
    // All four train-step variants must compute the same loss (within
    // bf16 tolerance) for identical params+batch — the Fig. 8 invariant
    // at the artifact level.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use bertdist::data::masking::{build_batch, MaskingConfig};
    use bertdist::data::PairExample;
    use bertdist::trainer::init_params;
    use bertdist::util::Pcg64;

    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let mut rng = Pcg64::new(4);
    let params = init_params(&model.layout, &mut rng);
    let ex = PairExample {
        tokens_a: (10..22).collect(),
        tokens_b: (40..52).collect(),
        is_next: false,
    };
    let cfg = MaskingConfig { vocab_size: 512, ..Default::default() };
    let batch = build_batch(&[ex.clone(), ex], 32, &cfg, &mut rng);

    let mut losses = Vec::new();
    for variant in ["unfused_f32", "fused_f32", "bf16", "fused_bf16"] {
        let step = engine.train_step("bert-micro", variant, 2, 32).unwrap();
        let out = step.run(&params, &batch, 1.0).unwrap();
        losses.push((variant, out.loss));
    }
    let f32_loss = losses[0].1;
    for (variant, loss) in &losses {
        let tol = if variant.contains("bf16") { 0.03 } else { 1e-4 };
        assert!(((loss - f32_loss) / f32_loss).abs() < tol,
                "{variant}: {loss} vs {f32_loss}");
    }
}

#[test]
fn grads_identical_across_replicas_after_allreduce() {
    // The core data-parallel invariant: after sync, every rank holds the
    // same averaged gradient.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use bertdist::collectives::CollectiveGroup;
    use bertdist::data::masking::{build_batch, MaskingConfig};
    use bertdist::data::PairExample;
    use bertdist::trainer::init_params;
    use bertdist::util::Pcg64;

    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let step = engine.train_step("bert-micro", "fused_f32", 2, 32).unwrap();
    let mut rng = Pcg64::new(6);
    let params = init_params(&model.layout, &mut rng);
    let cfg = MaskingConfig { vocab_size: 512, ..Default::default() };

    // each "rank" computes grads on different data
    let grads: Vec<Vec<f32>> = (0..3u32)
        .map(|r| {
            let ex = PairExample {
                tokens_a: (10 + r..24 + r).collect(),
                tokens_b: (40 + r..52 + r).collect(),
                is_next: r % 2 == 0,
            };
            let b = build_batch(&[ex.clone(), ex], 32, &cfg, &mut rng);
            step.run(&params, &b, 1.0).unwrap().grads
        })
        .collect();

    // serial average
    let n = grads[0].len();
    let mut want = vec![0.0f32; n];
    for g in &grads {
        for (w, x) in want.iter_mut().zip(g) {
            *w += x / 3.0;
        }
    }

    // threaded allreduce_mean
    let handles = CollectiveGroup::new(3);
    let joins: Vec<_> = handles
        .into_iter()
        .zip(grads)
        .map(|(mut h, mut g)| {
            std::thread::spawn(move || {
                h.allreduce_mean(&mut g);
                g
            })
        })
        .collect();
    let results: Vec<Vec<f32>> =
        joins.into_iter().map(|j| j.join().unwrap()).collect();
    for r in &results {
        bertdist::testkit::assert_allclose(r, &want, 1e-6, 1e-4);
    }
    // all replicas identical
    for r in &results[1..] {
        assert_eq!(r.len(), results[0].len());
        bertdist::testkit::assert_allclose(r, &results[0], 0.0, 0.0);
    }
}

#[test]
fn dataset_partition_covers_everything_once() {
    let dir = std::env::temp_dir().join("bertdist_it_partition");
    make_data(&dir, 512, 8);
    let world = 4;
    let ds = prepare_datasets(&dir, world).unwrap();
    let total: usize = ds.iter().map(|d| d.len()).sum();
    // all shards assigned, 2 shards per rank
    for d in &ds {
        assert_eq!(d.shard_paths().len(), 2);
    }
    let ds1 = prepare_datasets(&dir, 1).unwrap();
    assert_eq!(total, ds1[0].len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn comm_modes_train_to_close_params() {
    // ISSUE 2: `train.comm_mode` picks the bucket route (flat world ring
    // vs §4.4 PCIe-then-network hierarchy).  The two schedules associate
    // the gradient sum differently, so trained parameters agree to
    // rounding (bitwise equality on exact sums is covered by
    // pool_overlap.rs) and both runs must be finite and learn.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use bertdist::trainer::CommMode;
    let dir = std::env::temp_dir().join("bertdist_it_comm_mode");
    make_data(&dir, 512, 4);
    let engine = Engine::cpu(&art).unwrap();
    let datasets = prepare_datasets(&dir, 4).unwrap();
    let mut finals: Vec<Vec<f32>> = Vec::new();
    for mode in [CommMode::Flat, CommMode::Hierarchical] {
        let mut cfg = base_cfg("2M2G");
        cfg.train.comm_mode = mode;
        let mut t = bertdist::trainer::Trainer::new(&engine, cfg, 32, 2)
            .unwrap();
        assert_eq!(t.is_hierarchical(), mode == CommMode::Hierarchical);
        let r = t.run(&datasets, 6, 6).unwrap();
        assert_eq!(r.steps, 6);
        assert!(r.loss.tail_mean(3).is_finite(), "{mode:?}");
        // per-phase exchange accounting: hierarchical splits PCIe/net,
        // and the overlap ratio is always a valid fraction
        let eff = r.exchange.overlap_efficiency();
        assert!((0.0..=1.0).contains(&eff), "{mode:?}: {eff}");
        if mode == CommMode::Hierarchical {
            assert!(r.exchange.pcie_comm_s > 0.0, "hier must bill PCIe");
            assert!(r.exchange.net_comm_s > 0.0, "hier must bill network");
        }
        finals.push(t.params.clone());
    }
    let max_rel = finals[0]
        .iter()
        .zip(finals[1].iter())
        .map(|(a, b)| (a - b).abs() / a.abs().max(b.abs()).max(1e-3))
        .fold(0.0f32, f32::max);
    assert!(max_rel < 5e-2,
            "flat vs hierarchical training diverged: {max_rel}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn topk_sparsified_training_tracks_dense_loss() {
    // ISSUE 10: `train.sparsify = topk:0.1` ships 10% of the gradient
    // coordinates over the network ring; the error-feedback residual
    // folds the dropped mass back in, so training must land within a
    // pinned tolerance of the dense run's loss — lossy wire, same
    // training story.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use bertdist::grad::sparsify::Sparsify;
    let dir = std::env::temp_dir().join("bertdist_it_topk_loss");
    make_data(&dir, 512, 4);
    let engine = Engine::cpu(&art).unwrap();
    let datasets = prepare_datasets(&dir, 4).unwrap();
    let mut tails: Vec<f64> = Vec::new();
    for sparsify in [Sparsify::None, Sparsify::TopK(0.1)] {
        let mut cfg = base_cfg("2M2G");
        cfg.train.sparsify = sparsify;
        let mut t = bertdist::trainer::Trainer::new(&engine, cfg, 32, 2)
            .unwrap();
        assert_eq!(t.sparsify_active(),
                   sparsify != Sparsify::None,
                   "2M2G must activate topk and leave dense alone");
        let r = t.run(&datasets, 20, 20).unwrap();
        assert_eq!(r.steps, 20);
        let head = r.loss.points[0].1;
        let tail = r.loss.tail_mean(5);
        assert!(tail.is_finite(), "{sparsify}");
        assert!(tail < head,
                "{sparsify}: training did not improve: {head} -> {tail}");
        tails.push(tail);
    }
    let (dense, sparse) = (tails[0], tails[1]);
    let rel = (sparse - dense).abs() / dense;
    assert!(rel < 0.25,
            "topk:0.1 loss diverged from dense beyond the pinned \
             tolerance: dense {dense}, sparse {sparse} (rel {rel})");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn topk_resume_mid_run_matches_uninterrupted_bitwise() {
    // ISSUE 10: interrupting a sparsified run and resuming from the
    // checkpoint must be invisible — the v2.2 error-feedback section
    // makes the residuals part of the resumable state, so the resumed
    // stream lands bitwise on the uninterrupted run's parameters.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use bertdist::checkpoint::Checkpoint;
    use bertdist::grad::sparsify::Sparsify;
    use bertdist::trainer::Trainer;
    let dir = std::env::temp_dir().join("bertdist_it_topk_resume");
    make_data(&dir, 512, 4);
    let engine = Engine::cpu(&art).unwrap();
    let datasets = prepare_datasets(&dir, 4).unwrap();
    let mut cfg = base_cfg("2M2G");
    cfg.train.sparsify = Sparsify::TopK(0.1);

    let mut ta = Trainer::new(&engine, cfg.clone(), 32, 2).unwrap();
    ta.run(&datasets, 6, 6).unwrap();
    let want = ta.checkpoint();
    assert!(!want.ef_residuals.is_empty(),
            "a live sparsifier must snapshot residuals");
    drop(ta);

    let ckdir = bertdist::testkit::tmp_ckpt_dir("it_topk_resume");
    let ck = ckdir.join("mid.bckp");
    let mut tb = Trainer::new(&engine, cfg.clone(), 32, 2).unwrap();
    tb.run(&datasets, 3, 6).unwrap();
    tb.save(&ck).unwrap();
    drop(tb);

    let mut tc = Trainer::new(&engine, cfg, 32, 2).unwrap();
    let loaded = Checkpoint::load(&ck).unwrap();
    assert!(!loaded.ef_residuals.is_empty(),
            "the mid-run checkpoint must carry the EF section");
    tc.restore(loaded).unwrap();
    tc.run(&datasets, 3, 6).unwrap();
    let got = tc.checkpoint();

    assert_eq!(got.step, want.step);
    assert_eq!(got.data_step, want.data_step);
    assert_eq!(got.scaler, want.scaler);
    for (name, a, b) in [("params", &got.params, &want.params),
                         ("m", &got.m, &want.m), ("v", &got.v, &want.v)] {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{name}[{i}] diverged after topk resume: {x} vs {y}");
        }
    }
    assert_eq!(got.ef_residuals.len(), want.ef_residuals.len());
    for (r, (a, b)) in got.ef_residuals
        .iter()
        .zip(want.ef_residuals.iter())
        .enumerate() {
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "ef[{r}][{i}] diverged after topk resume: {x} vs {y}");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
