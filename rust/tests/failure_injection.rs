//! Failure injection across the stack: corrupt shards, poisoned
//! gradients, missing artifacts, malformed manifests — the system must
//! fail loudly and recover where the design says it recovers.

use std::path::PathBuf;

use bertdist::data::{ShardedDataset};
use bertdist::precision::{has_nonfinite, DynamicLossScaler, StepVerdict};
use bertdist::runtime::{Engine, Manifest};
use bertdist::shard::{shard_file_name, ShardReader, ShardWriter};
use bertdist::util::Pcg64;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn corrupted_shard_record_fails_crc_not_garbage() {
    let dir = std::env::temp_dir().join("bertdist_fi_shard");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(shard_file_name("train", 0, 1));
    {
        let mut w = ShardWriter::create(&path).unwrap();
        let ex = bertdist::data::PairExample {
            tokens_a: vec![10, 11, 12],
            tokens_b: vec![20, 21],
            is_next: true,
        };
        for _ in 0..5 {
            w.append(&ex.to_bytes()).unwrap();
        }
        w.finish().unwrap();
    }
    // flip a byte inside record payloads
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[40] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    let mut r = ShardReader::open(&path).unwrap();
    let results: Vec<_> = (0..r.len()).map(|i| r.read(i)).collect();
    assert!(results.iter().any(|x| x.is_err()),
            "corruption must surface as an error");
    // opening through the dataset layer propagates the error
    assert!(ShardedDataset::open(&dir, "train", 0, 1).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scaler_rides_through_repeated_overflows() {
    let mut scaler = DynamicLossScaler::new(65536.0).with_growth_interval(8);
    let mut rng = Pcg64::new(88);
    let mut applied = 0;
    for _ in 0..500 {
        // 5% of steps produce non-finite grads
        let grads = if rng.chance(0.05) {
            vec![f32::NAN, 1.0]
        } else {
            vec![0.1, -0.2]
        };
        if scaler.update(has_nonfinite(&grads)) == StepVerdict::Apply {
            applied += 1;
        }
    }
    assert!(applied > 400, "most steps should still apply: {applied}");
    assert!(scaler.scale() >= 1.0 && scaler.scale().is_finite());
}

#[test]
fn missing_artifact_key_is_a_clean_error() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    let err = engine
        .train_step("bert-micro", "nonexistent_variant", 2, 32)
        .map(|_| ())
        .unwrap_err();
    assert!(err.to_string().contains("no train artifact"));
    let err = engine.apply_step("bert-micro", "adagrad").map(|_| ())
        .unwrap_err();
    assert!(err.to_string().contains("apply_adagrad"));
}

#[test]
fn wrong_batch_shape_is_rejected_before_pjrt() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use bertdist::data::masking::{build_batch, MaskingConfig};
    use bertdist::data::PairExample;
    use bertdist::trainer::init_params;

    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let step = engine.train_step("bert-micro", "fused_f32", 2, 32).unwrap();
    let mut rng = Pcg64::new(1);
    let params = init_params(&model.layout, &mut rng);
    let ex = PairExample { tokens_a: vec![10], tokens_b: vec![11],
                           is_next: true };
    let cfg = MaskingConfig { vocab_size: 512, ..Default::default() };
    // wrong seq (64 instead of 32)
    let bad = build_batch(&[ex.clone(), ex.clone()], 64, &cfg, &mut rng);
    assert!(step.run(&params, &bad, 1.0).is_err());
    // wrong param count
    let good = build_batch(&[ex.clone(), ex], 32, &cfg, &mut rng);
    assert!(step.run(&params[..10], &good, 1.0).is_err());
}

#[test]
fn malformed_manifest_is_rejected() {
    let dir = std::env::temp_dir().join("bertdist_fi_manifest");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // truncated JSON
    std::fs::write(dir.join("manifest.json"), "{\"models\": {").unwrap();
    assert!(Manifest::load(&dir).is_err());
    // valid JSON, wrong schema
    std::fs::write(dir.join("manifest.json"), "{\"models\": {\"m\": {}}}")
        .unwrap();
    assert!(Manifest::load(&dir).is_err());
    // layout/param_count inconsistency
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"models": {"m": {"config": {"vocab_size": 10, "hidden": 4,
            "layers": 1, "heads": 1, "intermediate": 8, "max_seq": 8,
            "type_vocab": 2}, "param_count": 999999,
            "layout": [{"name": "w", "offset": 0, "shape": [2]}],
            "artifacts": {}}}}"#,
    ).unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(err.to_string().contains("layout total"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dataset_open_with_more_ranks_than_shards_fails_clearly() {
    let dir = std::env::temp_dir().join("bertdist_fi_ranks");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    // One shard, two ranks: the shard set cannot cover the world, so
    // EVERY rank must fail the same way up front (ISSUE 3: the old code
    // let rank 0 open an oversized view and only starved rank 1).
    let path = dir.join(shard_file_name("train", 0, 1));
    let mut w = ShardWriter::create(&path).unwrap();
    w.append(&bertdist::data::PairExample {
        tokens_a: vec![10], tokens_b: vec![11], is_next: true,
    }.to_bytes()).unwrap();
    w.finish().unwrap();
    for rank in 0..2 {
        let err = ShardedDataset::open(&dir, "train", rank, 2).map(|_| ())
            .unwrap_err();
        assert!(err.to_string().contains("world 2"), "rank {rank}: {err}");
    }
    // a world the shard set does cover still opens fine
    assert!(ShardedDataset::open(&dir, "train", 0, 1).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn checkpoint_corruption_blocks_resume() {
    // (The full per-section truncate/flip matrix lives in
    // checkpoint_resume.rs.)
    let dir = bertdist::testkit::tmp_ckpt_dir("fi");
    let ck = bertdist::checkpoint::Checkpoint::new(64);
    let path = dir.join("fi.bckp");
    ck.save(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();
    assert!(bertdist::checkpoint::Checkpoint::load(&path).is_err());
}

// ---- deterministic fault injection (ISSUE 6 elasticity hook) ----

mod inject_fail {
    use bertdist::checkpoint::Checkpoint;
    use bertdist::config::RunConfig;
    use bertdist::coordinator::prepare_datasets;
    use bertdist::data::corpus::SyntheticCorpus;
    use bertdist::data::{build_shards, Vocab};
    use bertdist::runtime::Engine;
    use bertdist::testkit::{tmp_dir, train_to_step};
    use bertdist::topology::Topology;
    use bertdist::trainer::{InjectFail, Trainer};

    #[test]
    fn parse_accepts_step_and_optional_rank() {
        assert_eq!(InjectFail::parse("7").unwrap(),
                   InjectFail { step: 7, rank: None, net: false });
        assert_eq!(InjectFail::parse("7:2").unwrap(),
                   InjectFail { step: 7, rank: Some(2), net: false });
        assert_eq!(InjectFail::parse(" 3 : 1 ").unwrap(),
                   InjectFail { step: 3, rank: Some(1), net: false });
        for bad in ["", "x", "7:", ":1", "7:x", "1:2:3", "-1"] {
            let err = InjectFail::parse(bad).unwrap_err();
            assert!(err.to_string().contains("step[:rank]"),
                    "{bad:?}: {err}");
        }
    }

    #[test]
    fn parse_accepts_the_net_link_cut_form() {
        assert_eq!(InjectFail::parse("net:5").unwrap(),
                   InjectFail { step: 5, rank: None, net: true });
        assert_eq!(InjectFail::parse("net:5:1").unwrap(),
                   InjectFail { step: 5, rank: Some(1), net: true });
        assert_eq!(InjectFail::parse(" net:0 ").unwrap(),
                   InjectFail { step: 0, rank: None, net: true });
        for bad in ["net:", "net:x", "net:1:2:3", "net"] {
            let err = InjectFail::parse(bad).unwrap_err();
            assert!(err.to_string().contains("step[:rank]"),
                    "{bad:?}: {err}");
        }
    }

    fn bitwise(got: &Checkpoint, want: &Checkpoint, ctx: &str) {
        assert_eq!(got.step, want.step, "{ctx}: step");
        assert_eq!(got.data_step, want.data_step, "{ctx}: data_step");
        assert_eq!(got.scaler, want.scaler, "{ctx}: scaler");
        for (name, a, b) in [("params", &got.params, &want.params),
                             ("m", &got.m, &want.m),
                             ("v", &got.v, &want.v)] {
            assert_eq!(a.len(), b.len(), "{ctx}: {name} length");
            for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(),
                           "{ctx}: {name}[{i}]: {x} vs {y}");
            }
        }
    }

    fn cfg_and_data(tag: &str) -> Option<(Engine, RunConfig,
                                          Vec<bertdist::data::ShardedDataset>,
                                          bertdist::testkit::TempDir)> {
        let art = super::artifacts()?;
        let dir = tmp_dir(tag);
        let docs = SyntheticCorpus::new(9, 2_000).documents(24, 8, 10);
        let vocab = Vocab::from_documents(&docs, 512);
        vocab.save(&dir.join("vocab.txt")).unwrap();
        build_shards(&docs, &vocab, 4, dir.path(), "train", 9).unwrap();
        let mut cfg = RunConfig::default();
        cfg.train.preset = "bert-micro".into();
        cfg.train.variant = "fused_f32".into();
        cfg.train.lr = 1e-3;
        cfg.train.warmup_steps = 2;
        cfg.train.accum_steps = 2;
        cfg.train.log_every = 0;
        cfg.cluster.topo = Topology::parse("1M2G").unwrap();
        let engine = Engine::cpu(&art).unwrap();
        let datasets = prepare_datasets(dir.path(), 2).unwrap();
        Some((engine, cfg, datasets, dir))
    }

    /// A rank-targeted injection fires inside the pool at the final
    /// micro-step, names the rank and data_step in the error, applies
    /// nothing for the failed step — and the SAME trainer finishes the
    /// run bitwise-identically once the fault is cleared (replaying the
    /// failed step from its recorded data position).
    #[test]
    fn rank_targeted_injection_is_recoverable_and_deterministic() {
        let Some((engine, cfg, datasets, _dir)) =
            cfg_and_data("fi_inject_rank") else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let (tw, _) =
            train_to_step(&engine, &cfg, &datasets, 32, 2, 3, 3).unwrap();
        let want = tw.checkpoint();
        drop(tw);

        let mut t = Trainer::new(&engine, cfg, 32, 2).unwrap();
        t.set_inject_fail(Some(InjectFail { step: 1, rank: Some(1),
                                            net: false }));
        let err = t.run(&datasets, 3, 3).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected failure"), "{msg}");
        assert!(msg.contains("rank 1"), "{msg}");
        assert!(msg.contains("data_step 1"), "{msg}");
        // step 0 applied; the failed step 1 did not advance the stream
        assert_eq!(t.data_step(), 1);

        t.set_inject_fail(None);
        t.run(&datasets, 2, 3).unwrap();
        bitwise(&t.checkpoint(), &want, "post-fault rerun");
    }

    /// A rank-less injection fails the trainer loop before the step is
    /// dispatched: no pool traffic, no state change at all.
    #[test]
    fn rankless_injection_fails_before_touching_state() {
        let Some((engine, cfg, datasets, _dir)) =
            cfg_and_data("fi_inject_rankless") else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let mut t = Trainer::new(&engine, cfg, 32, 2).unwrap();
        let before = t.checkpoint();
        t.set_inject_fail(Some(InjectFail { step: 0, rank: None,
                                            net: false }));
        let err = t.run(&datasets, 2, 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("injected failure at data_step 0"), "{msg}");
        assert_eq!(t.data_step(), 0);
        bitwise(&t.checkpoint(), &before, "rank-less refusal");
    }
}

// ---- pooled exchange failure paths (ISSUE 2 hardening) ----

mod pool_failures {
    use bertdist::collectives::pool::{CollectivePool, CommMode, MicroStats,
                                      RankCompute, WireFormat};
    use bertdist::grad::BucketRange;
    use bertdist::topology::Topology;

    /// Fails (or panics) on one designated rank at the FINAL micro-step
    /// — after earlier micro-steps succeeded, the worst spot for the
    /// exchange protocol: every healthy rank has already begun feeding
    /// its comm worker eagerly.
    struct FailLate {
        n: usize,
        bad_rank: usize,
        panic: bool,
    }

    impl RankCompute for FailLate {
        fn micro(&self, rank: usize, _s: usize, micro: usize, _p: &[f32],
                 _sc: f32, out: &mut Vec<f32>)
                 -> anyhow::Result<MicroStats> {
            if rank == self.bad_rank && micro == 1 {
                if self.panic {
                    panic!("injected late panic on rank {rank}");
                }
                anyhow::bail!("injected late failure on rank {rank}");
            }
            out.resize(self.n, 0.0);
            out.fill(0.5);
            Ok(MicroStats::default())
        }
    }

    /// Healthy compute for the recovery step.
    struct Ones {
        n: usize,
    }
    impl RankCompute for Ones {
        fn micro(&self, _r: usize, _s: usize, _m: usize, _p: &[f32],
                 _sc: f32, out: &mut Vec<f32>)
                 -> anyhow::Result<MicroStats> {
            out.resize(self.n, 0.0);
            out.fill(1.0);
            Ok(MicroStats::default())
        }
    }

    /// A late failure on any rank — node leader, node member, or a flat
    /// rank — must release every peer (no stranded exchange), surface
    /// the failing rank in the error, and leave the pool usable.
    #[test]
    fn late_rank_failure_releases_peers_in_every_comm_mode() {
        let topo = Topology::parse("2M2G").unwrap();
        let n = 96;
        let ranges = BucketRange::even_split(n, 3);
        for mode in [CommMode::Flat, CommMode::Hierarchical] {
            // rank 2 is machine 1's LEADER, rank 3 its member
            for bad_rank in [2usize, 3] {
                for panic in [false, true] {
                    let mut pool = CollectivePool::with_topology(
                        topo, n, ranges.clone(), WireFormat::F32, mode);
                    let err = pool
                        .step(&[], 1.0, 2, 0, true,
                              &FailLate { n, bad_rank, panic })
                        .unwrap_err();
                    let msg = format!("{err:#}");
                    assert!(msg.contains(&format!("rank {bad_rank}")),
                            "{mode} bad={bad_rank} panic={panic}: {msg}");
                    // pool survives: next step is correct on all ranks
                    pool.step(&[], 1.0, 1, 1, true, &Ones { n }).unwrap();
                    for r in 0..topo.world_size() {
                        let g = pool.rank_grads(r);
                        assert!(g.iter().all(|&v| v == 4.0),
                                "{mode} rank {r} after recovery");
                    }
                }
            }
        }
    }
}

// ---- socket-level failure paths (ISSUE 7: REAL disconnects) ----

mod socket_failures {
    use bertdist::collectives::pool::{CollectivePool, CommMode,
                                      IntraNodeMode, MicroStats,
                                      RankCompute, WireFormat};
    use bertdist::collectives::SocketTransport;
    use bertdist::grad::sparsify::Sparsify;
    use bertdist::grad::BucketRange;
    use bertdist::topology::Topology;

    struct Ones {
        n: usize,
    }
    impl RankCompute for Ones {
        fn micro(&self, _r: usize, _s: usize, _m: usize, _p: &[f32],
                 _sc: f32, out: &mut Vec<f32>)
                 -> anyhow::Result<MicroStats> {
            out.resize(self.n, 0.0);
            out.fill(1.0);
            Ok(MicroStats::default())
        }
    }

    /// Fails every micro of one designated step (the peer that "dies").
    struct DieAt {
        n: usize,
        step: usize,
    }
    impl RankCompute for DieAt {
        fn micro(&self, _r: usize, s: usize, _m: usize, _p: &[f32],
                 _sc: f32, out: &mut Vec<f32>)
                 -> anyhow::Result<MicroStats> {
            anyhow::ensure!(s != self.step, "peer dying at step {s}");
            out.resize(self.n, 0.0);
            out.fill(1.0);
            Ok(MicroStats::default())
        }
    }

    fn probe_addrs(n: usize) -> Vec<String> {
        let ls: Vec<std::net::TcpListener> = (0..n)
            .map(|_| std::net::TcpListener::bind("127.0.0.1:0").unwrap())
            .collect();
        ls.iter()
            .map(|l| format!("127.0.0.1:{}",
                             l.local_addr().unwrap().port()))
            .collect()
    }

    fn pool_on(peers: &[String], p: usize, n: usize, timeout_s: f64)
        -> CollectivePool {
        let mut t = SocketTransport::with_hosts(
            2, &peers[p], peers.to_vec(), timeout_s).unwrap();
        CollectivePool::with_transport(
            Topology::new(2, 1), n, BucketRange::even_split(n, 2),
            WireFormat::F32, CommMode::Flat, IntraNodeMode::Auto, 1 << 16,
            Sparsify::None, &mut t).unwrap()
    }

    /// A peer process dying mid-exchange (its socket closes) must
    /// surface the PR-2 stranded-peer shape on the survivor — the
    /// failing step named in the error — instead of hanging the ring.
    #[test]
    fn dropped_socket_peer_surfaces_named_step_error() {
        let peers = probe_addrs(2);
        let n = 64;
        std::thread::scope(|scope| {
            let survivor = {
                let peers = peers.clone();
                scope.spawn(move || {
                    let mut pool = pool_on(&peers, 0, n, 30.0);
                    pool.step(&[], 1.0, 1, 0, true, &Ones { n }).unwrap();
                    pool.step(&[], 1.0, 1, 1, true, &Ones { n })
                        .map(|_| ())
                        .unwrap_err()
                })
            };
            let dying = {
                let peers = peers.clone();
                scope.spawn(move || {
                    let mut pool = pool_on(&peers, 1, n, 30.0);
                    pool.step(&[], 1.0, 1, 0, true, &Ones { n }).unwrap();
                    // step 1: compute fails, the pool drops — comm
                    // workers exit and the TCP links close mid-step
                    pool.step(&[], 1.0, 1, 1, true,
                              &DieAt { n, step: 1 })
                        .map(|_| ())
                        .unwrap_err();
                })
            };
            dying.join().expect("dying peer thread panicked");
            let err = survivor.join().expect("survivor thread panicked");
            let msg = format!("{err:#}");
            assert!(msg.contains("pooled step 1 failed"), "{msg}");
            assert!(msg.contains("ring peer lost"), "{msg}");
        });
    }

    /// A peer that wired up but never exchanges (hung process, dead
    /// NIC) trips the `train.net_timeout_s` knob: the survivor's recv
    /// times out with the configured horizon in the message rather
    /// than blocking forever.
    #[test]
    fn quiet_socket_peer_trips_net_timeout() {
        let peers = probe_addrs(2);
        let n = 48;
        std::thread::scope(|scope| {
            let (quiet_tx, quiet_rx) = std::sync::mpsc::channel::<()>();
            let survivor = {
                let peers = peers.clone();
                scope.spawn(move || {
                    let mut pool = pool_on(&peers, 0, n, 0.3);
                    let err = pool
                        .step(&[], 1.0, 1, 0, true, &Ones { n })
                        .map(|_| ())
                        .unwrap_err();
                    let _ = quiet_tx.send(()); // release the quiet peer
                    err
                })
            };
            let quiet = {
                let peers = peers.clone();
                scope.spawn(move || {
                    // wires the links, then never steps
                    let pool = pool_on(&peers, 1, n, 30.0);
                    quiet_rx.recv().ok();
                    drop(pool);
                })
            };
            let err = survivor.join().expect("survivor thread panicked");
            quiet.join().expect("quiet peer thread panicked");
            let msg = format!("{err:#}");
            assert!(msg.contains("pooled step 0 failed"), "{msg}");
            assert!(msg.contains("net timeout"), "{msg}");
            assert!(msg.contains("0.3"), "{msg}");
        });
    }

    /// End to end over real processes: two `train` peers on loopback
    /// sockets; one dies (deterministically, via --inject-fail — its
    /// process exits and its sockets close).  The survivor must exit
    /// nonzero with the stranded-peer error naming the step, within
    /// the net timeout — not hang.
    #[cfg(unix)]
    #[test]
    fn killed_train_peer_process_fails_survivor_loudly() {
        use std::process::{Command, Stdio};

        let Some(_art) = super::artifacts() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let dir = bertdist::testkit::tmp_dir("fi_socket_kill");
        let data = dir.join("data");
        let bin = env!("CARGO_BIN_EXE_bertdist");
        let out = Command::new(bin)
            .args(["shard-data", "--out", data.to_str().unwrap(),
                   "--docs", "12", "--shards", "2", "--vocab-size", "512"])
            .output().unwrap();
        assert!(out.status.success(),
                "{}", String::from_utf8_lossy(&out.stderr));

        let socks = [dir.join("a.sock"), dir.join("b.sock")];
        let table = format!("unix:{},unix:{}", socks[0].display(),
                            socks[1].display());
        let spawn = |i: usize, extra: &[&str]| {
            let mut c = Command::new(bin);
            c.args(["train", "--preset", "bert-micro", "--variant",
                    "fused_f32", "--steps", "6", "--accum", "1",
                    "--batch", "2", "--seq", "32", "--lr", "1e-3",
                    "--log-every", "0", "--topo", "2M1G",
                    "--data-dir", data.to_str().unwrap(),
                    "--net-timeout", "20",
                    "--listen",
                    &format!("unix:{}", socks[i].display()),
                    "--connect", &table])
                .args(extra)
                .stdout(Stdio::piped())
                .stderr(Stdio::piped());
            c.spawn().unwrap()
        };
        let survivor = spawn(0, &[]);
        // the "killed" peer: its process exits at data_step 3, closing
        // its sockets mid-run
        let dying = spawn(1, &["--inject-fail", "3"]);

        let dying = dying.wait_with_output().unwrap();
        assert!(!dying.status.success(), "dying peer must exit nonzero");
        assert!(String::from_utf8_lossy(&dying.stderr)
                    .contains("injected failure"),
                "{}", String::from_utf8_lossy(&dying.stderr));

        let survivor = survivor.wait_with_output().unwrap();
        let err = String::from_utf8_lossy(&survivor.stderr);
        assert!(!survivor.status.success(),
                "survivor must fail loudly, not finish: {err}");
        assert!(err.contains("pooled step 3 failed"), "{err}");
    }
}
