//! ISSUE 3 acceptance: the zero-copy compute hot path — per-rank batch
//! prefetch, recycled marshaling scratch, in-place optimizer apply —
//! must be BITWISE-identical to the synchronous fresh-literal path.
//!
//! The data/pool layers are covered without artifacts; the XLA-backed
//! marshaling/apply/trainer properties require `make artifacts` and skip
//! gracefully otherwise (same convention as e2e_train.rs).

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use bertdist::collectives::pool::{CollectivePool, CommMode, MicroStats,
                                  RankCompute, WireFormat};
use bertdist::config::RunConfig;
use bertdist::coordinator::prepare_datasets;
use bertdist::data::corpus::SyntheticCorpus;
use bertdist::data::masking::{build_batch, Batch, MaskingConfig};
use bertdist::data::prefetch::{BatchCursor, Prefetcher};
use bertdist::data::{build_shards, PairExample, ShardedDataset, Vocab};
use bertdist::grad::BucketRange;
use bertdist::runtime::{Engine, StepScratch};
use bertdist::topology::Topology;
use bertdist::trainer::{init_params, Trainer};
use bertdist::util::Pcg64;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn make_data(dir: &Path, vocab_size: usize, shards: usize) -> Vocab {
    let _ = std::fs::remove_dir_all(dir);
    std::fs::create_dir_all(dir).unwrap();
    let docs = SyntheticCorpus::new(9, 2_000).documents(24, 8, 10);
    let vocab = Vocab::from_documents(&docs, vocab_size);
    vocab.save(&dir.join("vocab.txt")).unwrap();
    build_shards(&docs, &vocab, shards, dir, "train", 9).unwrap();
    vocab
}

// ------------------------------------------------- pool-level bitwise --

/// Pool compute whose gradients are a pure function of the rank's next
/// batch, fed either by a prefetch ring or a synchronous cursor.  The
/// gradient values are small integers, so sums are exact in f32 and the
/// reduced buffers can be compared bitwise across feeds.
struct BatchDriven<'a> {
    feed: Feed<'a>,
    n: usize,
}

enum Feed<'a> {
    Prefetch(Prefetcher<'a>),
    Sync(Vec<Mutex<(BatchCursor<'a>, Batch)>>),
}

impl RankCompute for BatchDriven<'_> {
    fn micro(&self, rank: usize, _s: usize, _m: usize, _p: &[f32],
             _sc: f32, out: &mut Vec<f32>) -> anyhow::Result<MicroStats> {
        let (loss, seed) = match &self.feed {
            Feed::Prefetch(p) => {
                let (b, stall) = p.pop(rank)?;
                assert!(stall >= 0.0);
                let r = digest(&b);
                p.recycle(rank, b);
                r
            }
            Feed::Sync(lanes) => {
                let mut lane = lanes[rank].lock().unwrap();
                let (cursor, buf) = &mut *lane;
                cursor.fill_next(buf);
                digest(buf)
            }
        };
        out.resize(self.n, 0.0);
        for (i, v) in out.iter_mut().enumerate() {
            *v = ((seed + i as u64) % 31) as f32;
        }
        Ok(MicroStats { loss, ..Default::default() })
    }
}

/// (scalar stat, integer digest) of a batch — any bit flip in the batch
/// changes the gradients, so feed equality is what makes the reduced
/// buffers agree.
fn digest(b: &Batch) -> (f64, u64) {
    let mut h = 0u64;
    for &t in &b.input_ids {
        h = h.wrapping_mul(31).wrapping_add(t as u64);
    }
    for &l in &b.mlm_labels {
        h = h.wrapping_mul(31).wrapping_add(l as u64 & 0xFF);
    }
    (b.num_predictions() as f64, h % 97)
}

#[test]
fn pooled_steps_with_prefetch_match_sync_bitwise_across_configs() {
    // World sizes, accumulation depths, and both comm modes: the
    // prefetch ring must feed the pool the exact synchronous stream, so
    // every rank's reduced gradients and the scalar stats agree to the
    // bit.  No artifacts needed — gradients are batch digests.
    let dir = std::env::temp_dir().join("bertdist_zc_pool");
    let vocab = make_data(&dir, 512, 8);
    let mcfg = MaskingConfig {
        vocab_size: vocab.len() as u32,
        ..Default::default()
    };
    let n = 257;
    for (m, g, k, mode) in [
        (1usize, 2usize, 1usize, CommMode::Flat),
        (1, 4, 3, CommMode::Flat),
        (2, 2, 2, CommMode::Hierarchical),
        (3, 2, 2, CommMode::Auto),
    ] {
        let topo = Topology::new(m, g);
        let world = topo.world_size();
        let datasets = prepare_datasets(&dir, world).unwrap();
        let steps = 5;
        let mut sums: Vec<(Vec<f32>, f64)> = Vec::new();
        for depth in [0usize, 2] {
            let (grads, loss) = std::thread::scope(|scope| {
                let feed = if depth == 0 {
                    Feed::Sync(
                        datasets
                            .iter()
                            .map(|d| {
                                Mutex::new((
                                    BatchCursor::new(d, mcfg.clone(), 7, 4,
                                                     32, 0),
                                    Batch::zeros(4, 32),
                                ))
                            })
                            .collect(),
                    )
                } else {
                    Feed::Prefetch(Prefetcher::spawn(scope, &datasets,
                                                     &mcfg, 7, 4, 32, 0,
                                                     depth))
                };
                let compute = BatchDriven { feed, n };
                let mut pool = CollectivePool::with_topology(
                    topo, n, BucketRange::even_split(n, 3),
                    WireFormat::F32, mode);
                let mut loss = 0.0;
                for s in 0..steps {
                    loss += pool.step(&[], 1.0, k, s, true, &compute)
                        .unwrap()
                        .loss_sum;
                }
                let grads = pool.leader_grads().clone();
                (grads, loss)
            });
            sums.push((grads, loss));
        }
        let (ref gs, ls) = sums[0];
        let (ref gp, lp) = sums[1];
        assert_eq!(ls, lp, "{m}M{g}G k={k} {mode:?}: losses diverged");
        for (i, (a, b)) in gs.iter().zip(gp.iter()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(),
                       "{m}M{g}G k={k} {mode:?}: grad [{i}]");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------ marshaling scratch bitwise --

#[test]
fn scratch_reuse_matches_fresh_literals_bitwise() {
    // Satellite: N consecutive TrainStep::run_scratch calls through ONE
    // StepScratch must produce bitwise-identical outputs to fresh-
    // literal runs — across batch changes, params changes (version
    // bump), and loss-scale changes.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let step = engine.train_step("bert-micro", "fused_f32", 2, 32).unwrap();
    let mut rng = Pcg64::new(11);
    let mut params = init_params(&model.layout, &mut rng);
    let mcfg = MaskingConfig { vocab_size: 512, ..Default::default() };

    let mut scratch = StepScratch::new();
    let mut grads = vec![0.0f32; step.n_params];
    for i in 0..6u64 {
        let ex = PairExample {
            tokens_a: (10 + i as u32..24 + i as u32).collect(),
            tokens_b: (40..52).collect(),
            is_next: i % 2 == 0,
        };
        let mut brng = Pcg64::new(100 + i);
        let batch = build_batch(&[ex.clone(), ex], 32, &mcfg, &mut brng);
        let scale = if i % 3 == 0 { 1.0 } else { 2.0 };
        // params mutate exactly when the version bumps (the StepScratch
        // contract), so odd calls exercise the cache-hit path and even
        // calls the rebuild path
        if i > 0 && i % 2 == 0 {
            params[0] += 0.001;
        }
        let s = step
            .run_scratch(&mut scratch, &params, i / 2, &batch, scale,
                         &mut grads)
            .unwrap();
        let fresh = step.run(&params, &batch, scale).unwrap();
        assert_eq!(s.loss.to_bits(), fresh.loss.to_bits(), "call {i}");
        assert_eq!(s.mlm_loss.to_bits(), fresh.mlm_loss.to_bits());
        assert_eq!(s.nsp_loss.to_bits(), fresh.nsp_loss.to_bits());
        assert_eq!(s.mlm_acc.to_bits(), fresh.mlm_acc.to_bits());
        assert_eq!(s.grad_norm.to_bits(), fresh.grad_norm.to_bits());
        assert_eq!(grads.len(), fresh.grads.len());
        for (j, (a, b)) in grads.iter().zip(fresh.grads.iter()).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(), "call {i} grad [{j}]");
        }
    }
}

#[test]
fn apply_step_inplace_is_stable_over_100_reuses() {
    // Satellite: the in-place ApplyStep must never drift buffer lengths
    // and must match a fresh-buffer baseline bitwise across 100 reuse
    // iterations.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let apply = engine.apply_step("bert-micro", "lamb").unwrap();
    let n = model.param_count;
    let mut rng = Pcg64::new(33);
    let params0 = init_params(&model.layout, &mut rng);
    let grads: Vec<f32> =
        (0..n).map(|_| (rng.next_gaussian() * 0.01) as f32).collect();

    // reused buffers: the hot path
    let mut p = params0.clone();
    let mut m = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    // fresh-buffer baseline: clone state into brand-new Vecs each step
    let (mut pf, mut mf, mut vf) = (params0, vec![0.0f32; n],
                                    vec![0.0f32; n]);
    for s in 1..=100 {
        apply.run(&mut p, &grads, &mut m, &mut v, s as f32, 1e-3).unwrap();
        let (mut p2, mut m2, mut v2) =
            (pf.to_vec(), mf.to_vec(), vf.to_vec());
        apply.run(&mut p2, &grads, &mut m2, &mut v2, s as f32, 1e-3)
            .unwrap();
        (pf, mf, vf) = (p2, m2, v2);
        assert_eq!(p.len(), n, "params drifted at step {s}");
        assert_eq!(m.len(), n, "m drifted at step {s}");
        assert_eq!(v.len(), n, "v drifted at step {s}");
    }
    for (i, (a, b)) in p.iter().zip(pf.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param [{i}]");
    }
    for (i, (a, b)) in m.iter().zip(mf.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "m [{i}]");
    }
    for (i, (a, b)) in v.iter().zip(vf.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "v [{i}]");
    }
}

// ------------------------------------------------ trainer end to end --

#[test]
fn prefetched_training_is_bitwise_identical_to_synchronous() {
    // The headline acceptance criterion: prefetched + recycled training
    // produces bitwise-identical losses and parameters to the
    // synchronous path, across world sizes, accumulation depths, and
    // both comm modes.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    for (topo, accum, mode) in [
        ("1M2G", 1usize, CommMode::Flat),
        ("1M2G", 2, CommMode::Flat),
        ("2M2G", 2, CommMode::Hierarchical),
        ("2M2G", 1, CommMode::Auto),
    ] {
        let dir = std::env::temp_dir()
            .join(format!("bertdist_zc_train_{topo}_{accum}_{mode}"));
        make_data(&dir, 512, 4);
        let world = Topology::parse(topo).unwrap().world_size();
        let datasets = prepare_datasets(&dir, world).unwrap();
        let mut finals: Vec<(Vec<f32>, Vec<(usize, f64)>, f64)> =
            Vec::new();
        for depth in [2usize, 0] {
            let mut cfg = RunConfig::default();
            cfg.train.preset = "bert-micro".into();
            cfg.train.variant = "fused_f32".into();
            cfg.train.lr = 1e-3;
            cfg.train.warmup_steps = 2;
            cfg.train.accum_steps = accum;
            cfg.train.log_every = 0;
            cfg.train.comm_mode = mode;
            cfg.train.prefetch_depth = depth;
            cfg.cluster.topo = Topology::parse(topo).unwrap();
            let mut t = Trainer::new(&engine, cfg, 32, 2).unwrap();
            let r = t.run(&datasets, 5, 5).unwrap();
            assert_eq!(r.steps, 5);
            assert!(r.input_stall_s >= 0.0);
            assert!((0.0..=1.0).contains(&r.data_efficiency),
                    "{topo} k={accum}: data_eff {}", r.data_efficiency);
            finals.push((t.params.clone(), r.loss.points.clone(),
                         r.input_stall_s));
        }
        assert_eq!(finals[0].1, finals[1].1,
                   "{topo} k={accum} {mode:?}: loss curves diverged");
        for (i, (a, b)) in
            finals[0].0.iter().zip(finals[1].0.iter()).enumerate()
        {
            assert_eq!(a.to_bits(), b.to_bits(),
                       "{topo} k={accum} {mode:?}: param [{i}]");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn long_run_reshuffles_epochs_deterministically() {
    // Satellite regression: the epoch order must advance when a rank's
    // batch index wraps its epoch length (the old trainer computed
    // `epoch_order(step / 100, seed)` once and never reshuffled).
    let dir = std::env::temp_dir().join("bertdist_zc_epochs");
    let vocab = make_data(&dir, 512, 2);
    let ds = ShardedDataset::open(&dir, "train", 0, 1).unwrap();
    let mcfg = MaskingConfig {
        vocab_size: vocab.len() as u32,
        ..Default::default()
    };
    let mut cursor = BatchCursor::new(&ds, mcfg.clone(), 42, 4, 32, 0);
    let bpe = cursor.batches_per_epoch();
    // Drain two epochs, recording each epoch's first batch.
    let mut buf = Batch::zeros(4, 32);
    let mut first_batches = Vec::new();
    for e in 0..2u64 {
        for i in 0..bpe {
            cursor.fill_next(&mut buf);
            if i == 0 {
                // the epoch advances lazily, on the fill that crosses
                // the boundary
                assert_eq!(cursor.epoch() as u64, e);
                first_batches.push(buf.clone());
            }
        }
    }
    assert_eq!(cursor.epoch(), 1);
    // different epoch orders -> different leading batches (the masking
    // stream alone cannot explain identical token ids)
    assert_ne!(first_batches[0].input_ids, first_batches[1].input_ids,
               "epoch 1 replayed epoch 0's order");
    // and the whole stream is reproducible
    let mut replay = BatchCursor::new(&ds, mcfg, 42, 4, 32, 0);
    let mut rbuf = Batch::zeros(4, 32);
    replay.fill_next(&mut rbuf);
    assert_eq!(rbuf, first_batches[0]);
    let _ = std::fs::remove_dir_all(&dir);
}
