//! Transport-layer integration tests: the golden v1 wire fixture, and
//! the ISSUE-7 property — a pooled exchange over `SocketTransport`
//! (loopback, world split across transports) is BITWISE identical to
//! the same exchange over `InProcTransport`, across comm modes and
//! wire formats.

use std::sync::Arc;

use bertdist::collectives::pool::{CollectivePool, CommMode, IntraNodeMode,
                                  MicroStats, RankCompute, WireFormat};
use bertdist::collectives::transport::{decode_frame, encode_frame,
                                       PayloadPool};
use bertdist::collectives::{Frame, SocketTransport, Transport};
use bertdist::grad::sparsify::Sparsify;
use bertdist::grad::{bucket_ranges, build_buckets, BucketRange};
use bertdist::model::layout::ParamLayout;
use bertdist::topology::Topology;

// ---------------------------------------------------------------------------
// golden wire-format fixture
// ---------------------------------------------------------------------------

/// The four frames pinned in `tests/data/golden_frame_v1.bin`, in file
/// order.  Values exercise sign, zero, and the f16 edge (65504 = f16
/// MAX as an f32 payload; 0x3C00/0xC100 = f16 1.0/-2.5 on the wire).
fn golden_frames() -> Vec<Frame> {
    vec![
        Frame::Bucket { idx: 3, data: vec![0.0, -1.5, 3.25, 65504.0] },
        Frame::Chunk { idx: 3, chunk: 1, net_s: 0.25,
                       data: vec![1.0, -2.0] },
        Frame::RingF32 { tag: 7, data: vec![0.5, -0.5, 3.0] },
        Frame::RingF16 { tag: 107, data: vec![0x3C00, 0xC100, 0x0000] },
    ]
}

#[test]
fn golden_frame_fixture_is_byte_exact() {
    // Encoding today must reproduce the pinned v1 bytes exactly — any
    // layout drift breaks cross-version/cross-machine rings and fails
    // here, the way golden_v1.bckp pins checkpoints.
    let golden: &[u8] = include_bytes!("data/golden_frame_v1.bin");
    let mut ours = Vec::new();
    let mut scratch = Vec::new();
    for f in golden_frames() {
        encode_frame(&f, &mut scratch);
        ours.extend_from_slice(&scratch);
    }
    assert_eq!(ours.as_slice(), golden,
               "wire layout drifted from golden_frame_v1.bin");
}

#[test]
fn golden_frame_fixture_round_trips() {
    // And decoding the pinned bytes must yield the original frames.
    let golden: &[u8] = include_bytes!("data/golden_frame_v1.bin");
    let mut pool = PayloadPool::default();
    let mut at = 0;
    let mut decoded = Vec::new();
    while at < golden.len() {
        let len = u32::from_le_bytes(golden[at..at + 4].try_into()
            .unwrap()) as usize;
        let body = &golden[at + 4..at + 4 + len];
        decoded.push(decode_frame(body, &mut pool).unwrap());
        at += 4 + len;
    }
    assert_eq!(at, golden.len(), "trailing bytes in fixture");
    assert_eq!(decoded, golden_frames());
}

// ---------------------------------------------------------------------------
// socket == in-proc, bitwise
// ---------------------------------------------------------------------------

/// Deterministic per-(rank, step, micro, index) gradients.  Every value
/// is a small multiple of 0.125, so sums are exact in f32 under ANY
/// association — bitwise differences can only come from the exchange
/// itself.
struct ExactGrads {
    n: usize,
}

impl RankCompute for ExactGrads {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             _p: &[f32], _sc: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        for (i, v) in out.iter_mut().enumerate() {
            *v = (rank as f32 + 1.0) * 0.25
                + (i % 29) as f32 * 0.5
                + step_index as f32
                + micro as f32 * 0.125;
        }
        Ok(MicroStats::default())
    }
}

fn test_shape(n_a: usize, n_b: usize) -> (usize, Arc<[BucketRange]>) {
    let layout = ParamLayout::from_shapes(&[
        ("a".into(), vec![n_a]),
        ("b".into(), vec![n_b]),
    ]);
    let ranges = bucket_ranges(&build_buckets(&layout, 64));
    (layout.total_len(), ranges)
}

/// Fresh loopback TCP addresses: bind-to-:0 probes, then released for
/// the transports to claim.
fn probe_addrs(n: usize) -> Vec<String> {
    let listeners: Vec<std::net::TcpListener> = (0..n)
        .map(|_| std::net::TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    listeners
        .iter()
        .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
        .collect()
}

/// Run `steps` pooled exchanges with the world split over `nprocs`
/// socket transports (one thread standing in for each process) and
/// return every rank's reduced gradients in world order.
#[allow(clippy::too_many_arguments)]
fn socket_world_grads(topo: Topology, nprocs: usize, wire: WireFormat,
                      mode: CommMode, intra: IntraNodeMode, chunk: usize,
                      n: usize, ranges: &Arc<[BucketRange]>, steps: usize,
                      k: usize) -> Vec<Vec<f32>> {
    let peers = probe_addrs(nprocs);
    let world = topo.world_size();
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); world];
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..nprocs)
            .map(|p| {
                let peers = peers.clone();
                let ranges = ranges.clone();
                scope.spawn(move || {
                    let mut t = SocketTransport::with_hosts(
                        world, &peers[p], peers.clone(), 30.0).unwrap();
                    let mut pool = CollectivePool::with_transport(
                        topo, n, ranges, wire, mode, intra, chunk,
                        Sparsify::None, &mut t)
                        .unwrap();
                    for s in 0..steps {
                        pool.step(&[], 1.0, k, s, true, &ExactGrads { n })
                            .unwrap();
                    }
                    pool.local_ranks()
                        .map(|r| pool.rank_grads(r).clone())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for (p, h) in handles.into_iter().enumerate() {
            let grads = h.join().expect("socket world thread panicked");
            let per = world / nprocs;
            for (i, g) in grads.into_iter().enumerate() {
                out[p * per + i] = g;
            }
        }
    });
    out
}

/// The in-proc reference for the same shape.
fn inproc_world_grads(topo: Topology, wire: WireFormat, mode: CommMode,
                      intra: IntraNodeMode, chunk: usize, n: usize,
                      ranges: &Arc<[BucketRange]>, steps: usize, k: usize)
                      -> Vec<Vec<f32>> {
    let mut pool = CollectivePool::with_intra(topo, n, ranges.clone(),
                                              wire, mode, intra, chunk);
    for s in 0..steps {
        pool.step(&[], 1.0, k, s, true, &ExactGrads { n }).unwrap();
    }
    (0..topo.world_size())
        .map(|r| pool.rank_grads(r).clone())
        .collect()
}

fn assert_bitwise(got: &[Vec<f32>], want: &[Vec<f32>], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: world size");
    for (r, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.len(), w.len(), "{ctx}: rank {r} length");
        for (i, (x, y)) in g.iter().zip(w).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(),
                       "{ctx}: rank {r} [{i}]: {x} != {y}");
        }
    }
}

#[test]
fn flat_socket_exchange_matches_inproc_bitwise() {
    // 2 "processes", one rank each, flat ring over loopback TCP.
    let topo = Topology::new(2, 1);
    let (n, ranges) = test_shape(90, 67);
    let sock = socket_world_grads(topo, 2, WireFormat::F32, CommMode::Flat,
                                  IntraNodeMode::Auto, 1 << 16, n, &ranges,
                                  2, 2);
    let inproc = inproc_world_grads(topo, WireFormat::F32, CommMode::Flat,
                                    IntraNodeMode::Auto, 1 << 16, n,
                                    &ranges, 2, 2);
    assert_bitwise(&sock, &inproc, "flat f32");
}

#[test]
fn flat_socket_f16_wire_matches_inproc_bitwise() {
    // The f16 quantize-own-chunk schedule must pick the same chunk on
    // both transports — same bits after the lossy hop.
    let topo = Topology::new(2, 1);
    let (n, ranges) = test_shape(90, 67);
    let sock = socket_world_grads(topo, 2, WireFormat::F16, CommMode::Flat,
                                  IntraNodeMode::Auto, 1 << 16, n, &ranges,
                                  2, 1);
    let inproc = inproc_world_grads(topo, WireFormat::F16, CommMode::Flat,
                                    IntraNodeMode::Auto, 1 << 16, n,
                                    &ranges, 2, 1);
    assert_bitwise(&sock, &inproc, "flat f16");
}

#[test]
fn hierarchical_socket_exchange_matches_inproc_bitwise() {
    // 2M2G split machine-per-process: the PCIe member links stay
    // in-memory inside each process, only the leader ring crosses the
    // sockets — exactly the paper's §4.4 resource split.
    let topo = Topology::new(2, 2);
    let (n, ranges) = test_shape(130, 77);
    for intra in [IntraNodeMode::Serial, IntraNodeMode::Ring] {
        let sock = socket_world_grads(topo, 2, WireFormat::F32,
                                      CommMode::Hierarchical, intra, 48, n,
                                      &ranges, 2, 1);
        let inproc = inproc_world_grads(topo, WireFormat::F32,
                                        CommMode::Hierarchical, intra, 48,
                                        n, &ranges, 2, 1);
        assert_bitwise(&sock, &inproc, &format!("hier {intra:?}"));
    }
}

#[test]
fn socket_exchange_matches_spawn_baseline_bitwise() {
    // Close the ISSUE-7 triangle: socket pool == spawn-per-step
    // baseline too (the in-proc pool == baseline leg lives in
    // trainer::tests).
    use bertdist::grad::GradAccumulator;
    use bertdist::trainer::allreduce_buckets;

    let topo = Topology::new(2, 1);
    let layout = ParamLayout::from_shapes(&[
        ("a".into(), vec![90]),
        ("b".into(), vec![67]),
    ]);
    let n = layout.total_len();
    let buckets = build_buckets(&layout, 64);
    let ranges = bucket_ranges(&buckets);

    let sock = socket_world_grads(topo, 2, WireFormat::F32, CommMode::Flat,
                                  IntraNodeMode::Auto, 1 << 16, n, &ranges,
                                  1, 1);

    let grads = ExactGrads { n };
    let mut accs: Vec<GradAccumulator> =
        (0..2).map(|_| GradAccumulator::new(n)).collect();
    for (r, acc) in accs.iter_mut().enumerate() {
        let mut g = Vec::new();
        grads.micro(r, 0, 0, &[], 1.0, &mut g).unwrap();
        acc.add(&g);
    }
    allreduce_buckets(&mut accs, &buckets);
    let baseline: Vec<Vec<f32>> =
        accs.iter().map(|a| a.buffer().to_vec()).collect();
    assert_bitwise(&sock, &baseline, "socket vs spawn baseline");
}

// ---------------------------------------------------------------------------
// authenticated handshake (ISSUE 8)
// ---------------------------------------------------------------------------

use bertdist::collectives::transport::{LinkId, LinkKind};

/// Drive one cross-process edge: rank 0 dials (sends the handshake),
/// rank 1 accepts (verifies it).  Returns the accept side's result —
/// where every auth failure surfaces, since the dialer never waits for
/// an acknowledgement.
fn handshake_pair(auth0: Option<(&[u8], [u8; 8])>,
                  auth1: Option<(&[u8], [u8; 8])>)
                  -> Result<(), String> {
    let peers = probe_addrs(2);
    let id = LinkId { kind: LinkKind::FlatRing, from: 0, to: 1 };
    let auth0 = auth0.map(|(k, n)| (k.to_vec(), n));
    let auth1 = auth1.map(|(k, n)| (k.to_vec(), n));
    std::thread::scope(|scope| {
        let p = peers.clone();
        let dialer = scope.spawn(move || {
            let mut t = SocketTransport::with_hosts(
                2, &p[0], p.clone(), 10.0).unwrap();
            if let Some((k, n)) = auth0 {
                t.set_auth(&k, n);
            }
            // dial returns as soon as the handshake bytes are written
            t.link(id).map(|_| ()).map_err(|e| e.to_string())
        });
        let p = peers.clone();
        let acceptor = scope.spawn(move || {
            let mut t = SocketTransport::with_hosts(
                2, &p[1], p.clone(), 10.0).unwrap();
            if let Some((k, n)) = auth1 {
                t.set_auth(&k, n);
            }
            t.link(id).map(|_| ()).map_err(|e| e.to_string())
        });
        dialer.join().unwrap().expect("dial side never verifies");
        acceptor.join().unwrap()
    })
}

#[test]
fn matching_keys_and_nonce_shake_hands() {
    handshake_pair(Some((b"shared-secret", [9u8; 8])),
                   Some((b"shared-secret", [9u8; 8])))
        .expect("matching v2 handshake must be accepted");
}

#[test]
fn unauthenticated_pair_still_shakes_hands() {
    // No key on either side: the v1 handshake keeps working.
    handshake_pair(None, None)
        .expect("v1 handshake must stay accepted when no key is set");
}

#[test]
fn wrong_key_is_rejected_as_mac_mismatch() {
    let err = handshake_pair(Some((b"key-a", [9u8; 8])),
                             Some((b"key-b", [9u8; 8])))
        .expect_err("wrong key must be rejected");
    assert!(err.contains("MAC mismatch"), "got: {err}");
}

#[test]
fn stale_nonce_is_rejected_as_nonce_mismatch() {
    // Same key, different per-run nonce: a process from an earlier
    // generation (or a foreign run of the same job) is named as such.
    let err = handshake_pair(Some((b"shared-secret", [1u8; 8])),
                             Some((b"shared-secret", [2u8; 8])))
        .expect_err("stale nonce must be rejected");
    assert!(err.contains("nonce mismatch"), "got: {err}");
}

#[test]
fn v1_peer_is_rejected_when_a_key_is_required() {
    let err = handshake_pair(None, Some((b"shared-secret", [9u8; 8])))
        .expect_err("unauthenticated peer must be rejected");
    assert!(err.contains("unauthenticated v1 handshake"), "got: {err}");
}

#[test]
fn v2_peer_is_rejected_when_no_key_is_set() {
    let err = handshake_pair(Some((b"shared-secret", [9u8; 8])), None)
        .expect_err("authenticated peer must be rejected by keyless side");
    assert!(err.contains("no --net-key"), "got: {err}");
}

#[test]
fn authenticated_socket_exchange_matches_inproc_bitwise() {
    // With matching keys on every process, the full pooled exchange is
    // untouched by authentication: same bits as the in-proc pool.
    let topo = Topology::new(2, 1);
    let (n, ranges) = test_shape(90, 67);
    let peers = probe_addrs(2);
    let world = topo.world_size();
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); world];
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|p| {
                let peers = peers.clone();
                let ranges = ranges.clone();
                scope.spawn(move || {
                    let mut t = SocketTransport::with_hosts(
                        world, &peers[p], peers.clone(), 30.0).unwrap();
                    t.set_auth(b"run-secret", [0x42; 8]);
                    t.set_connect_backoff(5, 10);
                    let mut pool = CollectivePool::with_transport(
                        topo, n, ranges, WireFormat::F32, CommMode::Flat,
                        IntraNodeMode::Auto, 1 << 16, Sparsify::None,
                        &mut t).unwrap();
                    for s in 0..2 {
                        pool.step(&[], 1.0, 2, s, true, &ExactGrads { n })
                            .unwrap();
                    }
                    pool.local_ranks()
                        .map(|r| pool.rank_grads(r).clone())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for (p, h) in handles.into_iter().enumerate() {
            for (i, g) in h.join().unwrap().into_iter().enumerate() {
                out[p + i] = g;
            }
        }
    });
    let inproc = inproc_world_grads(topo, WireFormat::F32, CommMode::Flat,
                                    IntraNodeMode::Auto, 1 << 16, n,
                                    &ranges, 2, 2);
    assert_bitwise(&out, &inproc, "authenticated flat f32");
}

#[test]
fn transport_reports_its_local_slice() {
    // The pool only hosts (and only serves grads for) its transport's
    // rank slice.
    let topo = Topology::new(2, 1);
    let (n, ranges) = test_shape(40, 25);
    let peers = probe_addrs(2);
    std::thread::scope(|scope| {
        for p in 0..2 {
            let peers = peers.clone();
            let ranges = ranges.clone();
            scope.spawn(move || {
                let mut t = SocketTransport::with_hosts(
                    2, &peers[p], peers.clone(), 30.0).unwrap();
                assert_eq!(t.local_ranks(), p..p + 1);
                assert!(!t.fully_local());
                let mut pool = CollectivePool::with_transport(
                    topo, n, ranges, WireFormat::F32, CommMode::Flat,
                    IntraNodeMode::Auto, 1 << 16, Sparsify::None,
                    &mut t).unwrap();
                assert_eq!(pool.local_ranks(), p..p + 1);
                assert_eq!(pool.is_lead(), p == 0);
                pool.step(&[], 1.0, 1, 0, true, &ExactGrads { n }).unwrap();
                let _ = pool.rank_grads(p); // local: fine
                let other = 1 - p;
                assert!(std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        let _ = pool.rank_grads(other);
                    })).is_err(), "non-local rank_grads must panic");
            });
        }
    });
}
