//! Integration tests for the persistent collective pool (ISSUE 1):
//!
//! * property: across random worlds / layouts / bucket thresholds /
//!   accumulation depths, the overlapped (eager, Fig. 2) pipeline
//!   produces **bitwise-identical** reduced gradients to the barrier
//!   path — for both the f32 and f16 wire formats — and the f32 wire
//!   matches a serial oracle within tolerance;
//! * endurance: one pool survives and reuses its workers across well
//!   over 100 steps with correct results throughout.

use std::sync::Arc;

use bertdist::collectives::pool::{CollectivePool, MicroStats, RankCompute,
                                  WireFormat};
use bertdist::grad::{bucket_ranges, build_buckets, BucketRange};
use bertdist::model::layout::ParamLayout;
use bertdist::testkit;
use bertdist::util::Pcg64;

/// Deterministic synthetic gradients: a pure function of
/// (salt, rank, step, micro, element) — identical no matter which
/// schedule or thread executes it.
struct Synth {
    n: usize,
    salt: u64,
}

impl RankCompute for Synth {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             _params: &[f32], _scale: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        let stream = (rank as u64) << 32
            | (step_index as u64) << 8
            | micro as u64;
        let mut rng = Pcg64::with_stream(self.salt, stream);
        for v in out.iter_mut() {
            *v = rng.next_f32() * 4.0 - 2.0;
        }
        Ok(MicroStats { loss: 1.0, ..Default::default() })
    }
}

/// Serial oracle: the elementwise sum over all ranks and micro-steps.
fn serial_sum(synth: &Synth, world: usize, step_index: usize, k: usize)
              -> Vec<f32> {
    let mut want = vec![0.0f32; synth.n];
    let mut g = Vec::new();
    for r in 0..world {
        for m in 0..k {
            synth.micro(r, step_index, m, &[], 1.0, &mut g).unwrap();
            for (w, x) in want.iter_mut().zip(&g) {
                *w += *x;
            }
        }
    }
    want
}

/// Run `steps` pooled steps and return every rank's reduced buffer.
fn run_pool(world: usize, n: usize, ranges: Arc<[BucketRange]>,
            wire: WireFormat, overlap: bool, k: usize, steps: usize,
            salt: u64) -> Vec<Vec<f32>> {
    let mut pool = CollectivePool::new(world, n, ranges, wire);
    let synth = Synth { n, salt };
    for s in 0..steps {
        pool.step(&[], 1.0, k, s, overlap, &synth).unwrap();
    }
    (0..world).map(|r| pool.rank_grads(r).clone()).collect()
}

fn random_layout(rng: &mut Pcg64) -> ParamLayout {
    let tensors = rng.range_usize(1, 12);
    let shapes: Vec<(String, Vec<usize>)> = (0..tensors)
        .map(|i| (format!("t{i}"), vec![rng.range_usize(1, 400)]))
        .collect();
    ParamLayout::from_shapes(&shapes)
}

#[test]
fn prop_overlap_bitwise_equals_barrier_across_worlds_and_thresholds() {
    testkit::check_msg(
        "pool-overlap≡barrier", 0x0B1_7, 12,
        |r: &mut Pcg64| {
            let world = r.range_usize(1, 5);
            let threshold = r.range_usize(1, 900);
            let k = r.range_usize(1, 4);
            let salt = r.next_u64();
            (world, threshold, k, salt)
        },
        |&(world, threshold, k, salt)| {
            let mut lrng = Pcg64::with_stream(salt, 0x1A7);
            let layout = random_layout(&mut lrng);
            let n = layout.total_len();
            let ranges = bucket_ranges(&build_buckets(&layout, threshold));
            let steps = 2;
            for wire in [WireFormat::F32, WireFormat::F16] {
                let eager = run_pool(world, n, ranges.clone(), wire, true,
                                     k, steps, salt);
                let barrier = run_pool(world, n, ranges.clone(), wire,
                                       false, k, steps, salt);
                for r in 0..world {
                    for (i, (a, b)) in
                        eager[r].iter().zip(barrier[r].iter()).enumerate() {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "{wire:?} world={world} rank={r} [{i}]: \
                                 {a} != {b}"
                            ));
                        }
                    }
                }
                // every replica bitwise identical after the exchange
                for r in 1..world {
                    if eager[0] != eager[r] {
                        return Err(format!(
                            "{wire:?} replicas diverged (rank {r})"
                        ));
                    }
                }
            }
            // f32 wire matches the serial oracle (last step's sums)
            let synth = Synth { n, salt };
            let want = serial_sum(&synth, world, steps - 1, k);
            let got = run_pool(world, n, ranges, WireFormat::F32, true, k,
                               steps, salt);
            let d = testkit::max_abs_diff(&got[0], &want);
            if d > 1e-2 {
                return Err(format!("oracle mismatch: max diff {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn pool_survives_and_reuses_workers_across_120_steps() {
    let (world, k, salt) = (3usize, 2usize, 0xD06_F00Du64);
    let layout = ParamLayout::from_shapes(&[
        ("emb".into(), vec![64, 32]),   // 2048
        ("w1".into(), vec![40, 40]),    // 1600
        ("b1".into(), vec![40]),        // 40
        ("head".into(), vec![300]),     // 300
    ]);
    let n = layout.total_len();
    let ranges = bucket_ranges(&build_buckets(&layout, 1024));
    assert!(ranges.len() >= 2, "need a multi-bucket plan");
    let mut pool = CollectivePool::new(world, n, ranges, WireFormat::F32);
    let synth = Synth { n, salt };
    for s in 0..120 {
        let out = pool.step(&[], 1.0, k, s, true, &synth).unwrap();
        assert!((out.loss_sum - (world * k) as f64).abs() < 1e-9,
                "step {s}: stats lost");
        if s % 20 == 0 || s == 119 {
            let want = serial_sum(&synth, world, s, k);
            testkit::assert_allclose(&pool.leader_grads(), &want, 1e-2,
                                     1e-4);
            // replicas stay bitwise identical through heavy reuse
            let leader = pool.leader_grads().clone();
            for r in 1..world {
                let other = pool.rank_grads(r);
                for (a, b) in leader.iter().zip(other.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "step {s} rank {r}");
                }
            }
        }
    }
}

#[test]
fn alternating_overlap_modes_on_one_pool_are_consistent() {
    // The same pool can serve barrier and eager steps interchangeably —
    // the schedules only differ in timing, never in result.
    let (world, n, salt) = (2usize, 1500usize, 0xA17Eu64);
    let layout =
        ParamLayout::from_shapes(&[("a".into(), vec![n])]);
    let ranges = bucket_ranges(&build_buckets(&layout, 256));
    let mut pool =
        CollectivePool::new(world, n, ranges.clone(), WireFormat::F32);
    let synth = Synth { n, salt };
    let mut per_mode: Vec<Vec<f32>> = Vec::new();
    for overlap in [true, false] {
        pool.step(&[], 1.0, 3, 7, overlap, &synth).unwrap();
        per_mode.push(pool.leader_grads().clone());
    }
    for (a, b) in per_mode[0].iter().zip(per_mode[1].iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn f16_wire_stays_within_half_precision_tolerance() {
    let (world, n, k, salt) = (3usize, 700usize, 2usize, 0xF16u64);
    let layout = ParamLayout::from_shapes(&[("a".into(), vec![n])]);
    let ranges = bucket_ranges(&build_buckets(&layout, 128));
    let f32_out = run_pool(world, n, ranges.clone(), WireFormat::F32, true,
                           k, 1, salt);
    let f16_out = run_pool(world, n, ranges, WireFormat::F16, true, k, 1,
                           salt);
    // one rounding per hop over a world-3 ring: comfortably within 1%
    testkit::assert_allclose(&f16_out[0], &f32_out[0], 5e-2, 1e-2);
}
