//! Integration tests for the persistent collective pool (ISSUEs 1 & 2):
//!
//! * property: across random worlds / layouts / bucket thresholds /
//!   accumulation depths, the overlapped (eager, Fig. 2) pipeline
//!   produces **bitwise-identical** reduced gradients to the barrier
//!   path — for both the f32 and f16 wire formats — and the f32 wire
//!   matches a serial oracle within tolerance;
//! * property (ISSUE 2): across random `<X>M<Y>G` topologies (including
//!   the `g = 1` / `m = 1` degenerates), both overlap modes, and both
//!   wire formats, the pooled **hierarchical** exchange, the pooled
//!   **flat** ring, and the old **spawn-per-step baseline** all produce
//!   bitwise-identical reduced gradients when the gradient sums are
//!   exactly representable (values on a dyadic grid, so every partial
//!   sum is exact in f32 AND f16 and the summation association cannot
//!   matter) — and agree within rounding tolerance on arbitrary floats;
//! * overlap-efficiency: the exposed-communication measurement is pure
//!   recv wait, so the derived `1 - exposed/total` ratio lands in
//!   `[0, 1]` in every mode;
//! * endurance: one pool survives and reuses its workers across well
//!   over 100 steps with correct results throughout.

use std::sync::Arc;

use bertdist::collectives::pool::{CollectivePool, CommMode, MicroStats,
                                  RankCompute, WireFormat};
use bertdist::grad::{bucket_ranges, build_buckets, BucketRange,
                     GradAccumulator};
use bertdist::metrics::ExchangeTimings;
use bertdist::model::layout::ParamLayout;
use bertdist::testkit;
use bertdist::topology::Topology;
use bertdist::trainer::allreduce_buckets;
use bertdist::util::Pcg64;

/// Deterministic synthetic gradients: a pure function of
/// (salt, rank, step, micro, element) — identical no matter which
/// schedule or thread executes it.
struct Synth {
    n: usize,
    salt: u64,
}

impl RankCompute for Synth {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             _params: &[f32], _scale: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        let stream = (rank as u64) << 32
            | (step_index as u64) << 8
            | micro as u64;
        let mut rng = Pcg64::with_stream(self.salt, stream);
        for v in out.iter_mut() {
            *v = rng.next_f32() * 4.0 - 2.0;
        }
        Ok(MicroStats { loss: 1.0, ..Default::default() })
    }
}

/// Serial oracle: the elementwise sum over all ranks and micro-steps.
fn serial_sum(synth: &Synth, world: usize, step_index: usize, k: usize)
              -> Vec<f32> {
    let mut want = vec![0.0f32; synth.n];
    let mut g = Vec::new();
    for r in 0..world {
        for m in 0..k {
            synth.micro(r, step_index, m, &[], 1.0, &mut g).unwrap();
            for (w, x) in want.iter_mut().zip(&g) {
                *w += *x;
            }
        }
    }
    want
}

/// Run `steps` pooled steps and return every rank's reduced buffer.
fn run_pool(world: usize, n: usize, ranges: Arc<[BucketRange]>,
            wire: WireFormat, overlap: bool, k: usize, steps: usize,
            salt: u64) -> Vec<Vec<f32>> {
    let mut pool = CollectivePool::new(world, n, ranges, wire);
    let synth = Synth { n, salt };
    for s in 0..steps {
        pool.step(&[], 1.0, k, s, overlap, &synth).unwrap();
    }
    (0..world).map(|r| pool.rank_grads(r).clone()).collect()
}

fn random_layout(rng: &mut Pcg64) -> ParamLayout {
    let tensors = rng.range_usize(1, 12);
    let shapes: Vec<(String, Vec<usize>)> = (0..tensors)
        .map(|i| (format!("t{i}"), vec![rng.range_usize(1, 400)]))
        .collect();
    ParamLayout::from_shapes(&shapes)
}

#[test]
fn prop_overlap_bitwise_equals_barrier_across_worlds_and_thresholds() {
    testkit::check_msg(
        "pool-overlap≡barrier", 0x0B1_7, 12,
        |r: &mut Pcg64| {
            let world = r.range_usize(1, 5);
            let threshold = r.range_usize(1, 900);
            let k = r.range_usize(1, 4);
            let salt = r.next_u64();
            (world, threshold, k, salt)
        },
        |&(world, threshold, k, salt)| {
            let mut lrng = Pcg64::with_stream(salt, 0x1A7);
            let layout = random_layout(&mut lrng);
            let n = layout.total_len();
            let ranges = bucket_ranges(&build_buckets(&layout, threshold));
            let steps = 2;
            for wire in [WireFormat::F32, WireFormat::F16] {
                let eager = run_pool(world, n, ranges.clone(), wire, true,
                                     k, steps, salt);
                let barrier = run_pool(world, n, ranges.clone(), wire,
                                       false, k, steps, salt);
                for r in 0..world {
                    for (i, (a, b)) in
                        eager[r].iter().zip(barrier[r].iter()).enumerate() {
                        if a.to_bits() != b.to_bits() {
                            return Err(format!(
                                "{wire:?} world={world} rank={r} [{i}]: \
                                 {a} != {b}"
                            ));
                        }
                    }
                }
                // every replica bitwise identical after the exchange
                for r in 1..world {
                    if eager[0] != eager[r] {
                        return Err(format!(
                            "{wire:?} replicas diverged (rank {r})"
                        ));
                    }
                }
            }
            // f32 wire matches the serial oracle (last step's sums)
            let synth = Synth { n, salt };
            let want = serial_sum(&synth, world, steps - 1, k);
            let got = run_pool(world, n, ranges, WireFormat::F32, true, k,
                               steps, salt);
            let d = testkit::max_abs_diff(&got[0], &want);
            if d > 1e-2 {
                return Err(format!("oracle mismatch: max diff {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn pool_survives_and_reuses_workers_across_120_steps() {
    let (world, k, salt) = (3usize, 2usize, 0xD06_F00Du64);
    let layout = ParamLayout::from_shapes(&[
        ("emb".into(), vec![64, 32]),   // 2048
        ("w1".into(), vec![40, 40]),    // 1600
        ("b1".into(), vec![40]),        // 40
        ("head".into(), vec![300]),     // 300
    ]);
    let n = layout.total_len();
    let ranges = bucket_ranges(&build_buckets(&layout, 1024));
    assert!(ranges.len() >= 2, "need a multi-bucket plan");
    let mut pool = CollectivePool::new(world, n, ranges, WireFormat::F32);
    let synth = Synth { n, salt };
    for s in 0..120 {
        let out = pool.step(&[], 1.0, k, s, true, &synth).unwrap();
        assert!((out.loss_sum - (world * k) as f64).abs() < 1e-9,
                "step {s}: stats lost");
        if s % 20 == 0 || s == 119 {
            let want = serial_sum(&synth, world, s, k);
            testkit::assert_allclose(&pool.leader_grads(), &want, 1e-2,
                                     1e-4);
            // replicas stay bitwise identical through heavy reuse
            let leader = pool.leader_grads().clone();
            for r in 1..world {
                let other = pool.rank_grads(r);
                for (a, b) in leader.iter().zip(other.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "step {s} rank {r}");
                }
            }
        }
    }
}

#[test]
fn alternating_overlap_modes_on_one_pool_are_consistent() {
    // The same pool can serve barrier and eager steps interchangeably —
    // the schedules only differ in timing, never in result.
    let (world, n, salt) = (2usize, 1500usize, 0xA17Eu64);
    let layout =
        ParamLayout::from_shapes(&[("a".into(), vec![n])]);
    let ranges = bucket_ranges(&build_buckets(&layout, 256));
    let mut pool =
        CollectivePool::new(world, n, ranges.clone(), WireFormat::F32);
    let synth = Synth { n, salt };
    let mut per_mode: Vec<Vec<f32>> = Vec::new();
    for overlap in [true, false] {
        pool.step(&[], 1.0, 3, 7, overlap, &synth).unwrap();
        per_mode.push(pool.leader_grads().clone());
    }
    for (a, b) in per_mode[0].iter().zip(per_mode[1].iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn f16_wire_stays_within_half_precision_tolerance() {
    let (world, n, k, salt) = (3usize, 700usize, 2usize, 0xF16u64);
    let layout = ParamLayout::from_shapes(&[("a".into(), vec![n])]);
    let ranges = bucket_ranges(&build_buckets(&layout, 128));
    let f32_out = run_pool(world, n, ranges.clone(), WireFormat::F32, true,
                           k, 1, salt);
    let f16_out = run_pool(world, n, ranges, WireFormat::F16, true, k, 1,
                           salt);
    // one rounding per hop over a world-3 ring: comfortably within 1%
    testkit::assert_allclose(&f16_out[0], &f32_out[0], 5e-2, 1e-2);
}

// ------------------------------------------------------ ISSUE 2 tests --

/// Deterministic synthetic gradients on a dyadic grid: multiples of 0.25
/// in [-2, 2].  With at most 4x4 ranks and 3 micro-steps, every partial
/// sum (under ANY association) is a multiple of 0.25 with magnitude
/// under 512, hence exactly representable in both f32 and f16 — so the
/// flat ring, the hierarchy, and the spawn baseline must agree to the
/// bit, on either wire format.
struct ExactSynth {
    n: usize,
    salt: u64,
}

impl RankCompute for ExactSynth {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             _params: &[f32], _scale: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        let stream = (rank as u64) << 32
            | (step_index as u64) << 8
            | micro as u64;
        let mut rng = Pcg64::with_stream(self.salt, stream);
        for v in out.iter_mut() {
            *v = (rng.range_usize(0, 17) as f32 - 8.0) * 0.25;
        }
        Ok(MicroStats { loss: 1.0, ..Default::default() })
    }
}

/// Run `steps` pooled steps under the given comm mode and return every
/// rank's reduced buffer plus the accumulated exchange timings.
#[allow(clippy::too_many_arguments)]
fn run_pool_mode(topo: Topology, n: usize, ranges: Arc<[BucketRange]>,
                 wire: WireFormat, mode: CommMode, overlap: bool, k: usize,
                 steps: usize, compute: &dyn RankCompute)
                 -> (Vec<Vec<f32>>, ExchangeTimings) {
    let mut pool =
        CollectivePool::with_topology(topo, n, ranges, wire, mode);
    let mut timings = ExchangeTimings::default();
    for s in 0..steps {
        let out = pool.step(&[], 1.0, k, s, overlap, compute).unwrap();
        assert!(out.exposed_comm_s >= 0.0);
        assert!(out.exposed_comm_s <= out.wall_s + 1e-9,
                "exposed {} > wall {}", out.exposed_comm_s, out.wall_s);
        timings.record(&out.bucket_s, &out.bucket_pcie_s,
                       &out.bucket_net_s, out.exposed_comm_s);
    }
    let grads = (0..topo.world_size())
        .map(|r| pool.rank_grads(r).clone())
        .collect();
    (grads, timings)
}

/// The old spawn-per-step exchange over the same gradients (f32 only).
fn run_spawn_baseline(topo: Topology, n: usize, threshold: usize,
                      layout: &ParamLayout, k: usize, steps: usize,
                      compute: &dyn RankCompute) -> Vec<Vec<f32>> {
    let world = topo.world_size();
    let buckets = build_buckets(layout, threshold);
    let mut accs: Vec<GradAccumulator> =
        (0..world).map(|_| GradAccumulator::new(n)).collect();
    let mut g = Vec::new();
    for s in 0..steps {
        for (r, acc) in accs.iter_mut().enumerate() {
            acc.reset();
            for m in 0..k {
                compute.micro(r, s, m, &[], 1.0, &mut g).unwrap();
                acc.add(&g);
            }
        }
        allreduce_buckets(&mut accs, &buckets);
    }
    accs.iter().map(|a| a.buffer().to_vec()).collect()
}

fn assert_bitwise(tag: &str, a: &[Vec<f32>], b: &[Vec<f32>])
                  -> Result<(), String> {
    for (r, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        for (i, (va, vb)) in x.iter().zip(y.iter()).enumerate() {
            if va.to_bits() != vb.to_bits() {
                return Err(format!("{tag}: rank {r} [{i}]: {va} != {vb}"));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_hierarchical_flat_and_spawn_baseline_bitwise_identical() {
    testkit::check_msg(
        "pool-hier≡flat≡spawn", 0x41E2_2, 8,
        |r: &mut Pcg64| {
            let machines = r.range_usize(1, 5);
            let gpus = r.range_usize(1, 5);
            let threshold = r.range_usize(1, 900);
            let k = r.range_usize(1, 4);
            let salt = r.next_u64();
            (machines, gpus, threshold, k, salt)
        },
        |&(machines, gpus, threshold, k, salt)| {
            let topo = Topology::new(machines, gpus);
            let mut lrng = Pcg64::with_stream(salt, 0x1A7);
            let layout = random_layout(&mut lrng);
            let n = layout.total_len();
            let ranges = bucket_ranges(&build_buckets(&layout, threshold));
            let steps = 1;
            let synth = ExactSynth { n, salt };

            // spawn baseline (f32) is the reference
            let base = run_spawn_baseline(topo, n, threshold, &layout, k,
                                          steps, &synth);
            for wire in [WireFormat::F32, WireFormat::F16] {
                for overlap in [true, false] {
                    let tag = format!(
                        "{topo} {wire:?} overlap={overlap} k={k}");
                    let (flat, flat_t) = run_pool_mode(
                        topo, n, ranges.clone(), wire, CommMode::Flat,
                        overlap, k, steps, &synth);
                    let (hier, hier_t) = run_pool_mode(
                        topo, n, ranges.clone(), wire,
                        CommMode::Hierarchical, overlap, k, steps, &synth);
                    assert_bitwise(&format!("{tag} hier vs flat"), &hier,
                                   &flat)?;
                    assert_bitwise(&format!("{tag} flat vs spawn"), &flat,
                                   &base)?;
                    // replicas identical within each mode
                    for grads in [&flat, &hier] {
                        for r in 1..topo.world_size() {
                            if grads[0] != grads[r] {
                                return Err(format!(
                                    "{tag}: replicas diverged (rank {r})"
                                ));
                            }
                        }
                    }
                    // the wait-only exposed measurement keeps the
                    // overlap ratio in [0, 1] in every mode
                    for t in [&flat_t, &hier_t] {
                        let e = t.overlap_efficiency();
                        if !(0.0..=1.0).contains(&e) {
                            return Err(format!(
                                "{tag}: overlap efficiency {e} not in \
                                 [0,1]"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn hierarchical_matches_flat_within_rounding_on_arbitrary_floats() {
    // On general floats the two schedules associate the sum differently,
    // so require tolerance-equality (bitwise is covered above on the
    // exact grid).
    let topo = Topology::new(3, 3);
    let (n, k, salt) = (801usize, 2usize, 0xFA57u64);
    let layout = ParamLayout::from_shapes(&[("a".into(), vec![n])]);
    let ranges = bucket_ranges(&build_buckets(&layout, 200));
    let synth = Synth { n, salt }; // arbitrary floats in [-2, 2)
    let (flat, _) = run_pool_mode(topo, n, ranges.clone(), WireFormat::F32,
                                  CommMode::Flat, true, k, 1, &synth);
    let (hier, _) = run_pool_mode(topo, n, ranges, WireFormat::F32,
                                  CommMode::Hierarchical, true, k, 1,
                                  &synth);
    for r in 0..topo.world_size() {
        testkit::assert_allclose(&hier[r], &flat[r], 1e-3, 1e-4);
    }
    // and both match the serial oracle
    let want = serial_sum(&synth, topo.world_size(), 0, k);
    testkit::assert_allclose(&hier[0], &want, 1e-2, 1e-3);
}

#[test]
fn overlap_efficiency_in_unit_interval_both_modes_and_schedules() {
    // The satellite-2 regression: exposed communication is measured as
    // pure recv wait, so `1 - exposed/total` cannot go negative — in
    // particular in BARRIER mode, where the old `acc_done.elapsed()`
    // measurement (which included the reduced-data copy-back) reported
    // nonzero "overlap" or negative ratios.
    let topo = Topology::new(2, 2);
    let (n, salt) = (2000usize, 0x0E_FFu64);
    let layout = ParamLayout::from_shapes(&[("a".into(), vec![n])]);
    let ranges = bucket_ranges(&build_buckets(&layout, 256));
    let synth = Synth { n, salt };
    for mode in [CommMode::Flat, CommMode::Hierarchical] {
        for overlap in [true, false] {
            let (_, t) = run_pool_mode(topo, n, ranges.clone(),
                                       WireFormat::F32, mode, overlap, 2,
                                       5, &synth);
            let e = t.overlap_efficiency();
            assert!((0.0..=1.0).contains(&e),
                    "{mode} overlap={overlap}: efficiency {e}");
            assert!(t.total_comm_s > 0.0);
            assert!(t.exposed_comm_s >= 0.0);
            // phase components are independent per-rank maxima: each is
            // bounded by the total and together they cover it (the
            // split can overstate across ranks, never understate)
            assert!(t.pcie_comm_s <= t.total_comm_s + 1e-9,
                    "{mode}: pcie exceeds total");
            assert!(t.net_comm_s <= t.total_comm_s + 1e-9,
                    "{mode}: net exceeds total");
            assert!(t.pcie_comm_s + t.net_comm_s
                        >= t.total_comm_s - 1e-9 * t.total_comm_s.max(1.0),
                    "{mode}: split understates the total");
        }
    }
}

#[test]
fn degenerate_and_square_topologies_bitwise_identical_deterministic() {
    // The property test samples topologies randomly; pin the degenerate
    // corners (g = 1, m = 1, 1x1) and the smallest true hierarchy (2x2)
    // deterministically, in both overlap modes and both wire formats.
    for (machines, gpus) in [(1usize, 1usize), (1, 4), (4, 1), (2, 2)] {
        let topo = Topology::new(machines, gpus);
        let salt = 0xD15C0u64 + (machines * 10 + gpus) as u64;
        let layout = ParamLayout::from_shapes(&[
            ("a".into(), vec![37]),
            ("b".into(), vec![301]),
            ("c".into(), vec![64]),
        ]);
        let n = layout.total_len();
        let threshold = 128;
        let ranges = bucket_ranges(&build_buckets(&layout, threshold));
        let synth = ExactSynth { n, salt };
        let k = 2;
        let base =
            run_spawn_baseline(topo, n, threshold, &layout, k, 1, &synth);
        for wire in [WireFormat::F32, WireFormat::F16] {
            for overlap in [true, false] {
                let (flat, _) = run_pool_mode(topo, n, ranges.clone(), wire,
                                              CommMode::Flat, overlap, k, 1,
                                              &synth);
                let (hier, _) = run_pool_mode(topo, n, ranges.clone(), wire,
                                              CommMode::Hierarchical,
                                              overlap, k, 1, &synth);
                assert_bitwise(&format!("{topo} {wire:?} hier vs flat"),
                               &hier, &flat)
                    .unwrap();
                assert_bitwise(&format!("{topo} {wire:?} flat vs spawn"),
                               &flat, &base)
                    .unwrap();
            }
        }
    }
}
