//! ISSUE 9 (tentpole): bandwidth-optimal 2-level reduce-scatter
//! exchange, plus the loud-fail protocol regressions.
//!
//! The headline property: across random `<X>M<Y>G` topologies
//! (including the `g = 1` / `m = 1` degenerates where the schedule
//! falls back to flat), random bucket thresholds (including buckets
//! smaller than a node — empty shards), accumulation depths, both
//! overlap modes and both wire formats, the **2-level reduce-scatter**
//! exchange, the **serialized-leader** schedule, the **flat world
//! ring**, and the old **spawn-per-step baseline** all produce
//! bitwise-identical reduced gradients on exact-sum gradients (dyadic
//! grid, so no summation association can matter).  The same equality
//! holds over `SocketTransport`.
//!
//! Plus the ISSUE-9 bugfix regressions: a peer that ships a truncated
//! ring payload, a skewed/short member bucket, a skewed chain chunk, or
//! a skewed broadcast now surfaces a NAMED protocol error — on both
//! transports — instead of silently truncating the reduce `zip` (or
//! only tripping a debug assert).

use std::ops::Range;
use std::sync::Arc;

use bertdist::collectives::pool::{CollectivePool, CommMode, IntraNodeMode,
                                  MicroStats, RankCompute, WireFormat};
use bertdist::collectives::transport::{FrameTx, InProcTransport, LinkEnds,
                                       LinkId, LinkKind, PayloadPool,
                                       Transport, TransportError};
use bertdist::collectives::{Frame, SocketTransport};
use bertdist::grad::sparsify::Sparsify;
use bertdist::grad::{bucket_ranges, build_buckets, BucketRange,
                     GradAccumulator};
use bertdist::model::layout::ParamLayout;
use bertdist::testkit;
use bertdist::topology::Topology;
use bertdist::trainer::allreduce_buckets;
use bertdist::util::Pcg64;

// ---------------------------------------------------------------------------
// shared fixtures
// ---------------------------------------------------------------------------

/// Deterministic synthetic gradients on a dyadic grid: multiples of
/// 0.25 in [-2, 2].  Every partial sum under ANY association is exactly
/// representable in both f32 and f16, so the 2-level reduce-scatter,
/// the serialized leader, the flat ring, and the spawn baseline must
/// all agree to the bit.
struct ExactSynth {
    n: usize,
    salt: u64,
}

impl RankCompute for ExactSynth {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             _params: &[f32], _scale: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        let stream = (rank as u64) << 32
            | (step_index as u64) << 8
            | micro as u64;
        let mut rng = Pcg64::with_stream(self.salt, stream);
        for v in out.iter_mut() {
            *v = (rng.range_usize(0, 17) as f32 - 8.0) * 0.25;
        }
        Ok(MicroStats { loss: 1.0, ..Default::default() })
    }
}

fn random_layout(rng: &mut Pcg64) -> ParamLayout {
    let tensors = rng.range_usize(1, 10);
    let shapes: Vec<(String, Vec<usize>)> = (0..tensors)
        .map(|i| (format!("t{i}"), vec![rng.range_usize(1, 400)]))
        .collect();
    ParamLayout::from_shapes(&shapes)
}

/// Run `steps` pooled steps under (mode, intra) and return every rank's
/// reduced buffer.
#[allow(clippy::too_many_arguments)]
fn run_pool(topo: Topology, n: usize, ranges: Arc<[BucketRange]>,
            wire: WireFormat, mode: CommMode, intra: IntraNodeMode,
            overlap: bool, k: usize, steps: usize,
            compute: &dyn RankCompute) -> Vec<Vec<f32>> {
    let mut pool = CollectivePool::with_intra(
        topo, n, ranges, wire, mode, intra, 1 << 16);
    for s in 0..steps {
        let out = pool.step(&[], 1.0, k, s, overlap, compute).unwrap();
        assert!(out.comm_net_s <= out.comm_s + 1e-9,
                "net {} > total {}", out.comm_net_s, out.comm_s);
    }
    (0..topo.world_size())
        .map(|r| pool.rank_grads(r).clone())
        .collect()
}

/// The old spawn-per-step exchange over the same gradients (f32 only).
fn run_spawn_baseline(topo: Topology, n: usize, threshold: usize,
                      layout: &ParamLayout, k: usize, steps: usize,
                      compute: &dyn RankCompute) -> Vec<Vec<f32>> {
    let world = topo.world_size();
    let buckets = build_buckets(layout, threshold);
    let mut accs: Vec<GradAccumulator> =
        (0..world).map(|_| GradAccumulator::new(n)).collect();
    let mut g = Vec::new();
    for s in 0..steps {
        for (r, acc) in accs.iter_mut().enumerate() {
            acc.reset();
            for m in 0..k {
                compute.micro(r, s, m, &[], 1.0, &mut g).unwrap();
                acc.add(&g);
            }
        }
        allreduce_buckets(&mut accs, &buckets);
    }
    accs.iter().map(|a| a.buffer().to_vec()).collect()
}

fn assert_bitwise(tag: &str, a: &[Vec<f32>], b: &[Vec<f32>])
                  -> Result<(), String> {
    for (r, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if x.len() != y.len() {
            return Err(format!("{tag}: rank {r} length {} != {}",
                               x.len(), y.len()));
        }
        for (i, (va, vb)) in x.iter().zip(y.iter()).enumerate() {
            if va.to_bits() != vb.to_bits() {
                return Err(format!("{tag}: rank {r} [{i}]: {va} != {vb}"));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// the headline property: rs ≡ serial ≡ flat ≡ spawn baseline, bitwise
// ---------------------------------------------------------------------------

#[test]
fn prop_rs_serial_flat_and_spawn_baseline_bitwise_identical() {
    testkit::check_msg(
        "rs≡serial≡flat≡spawn", 0x25C4, 8,
        |r: &mut Pcg64| {
            let machines = r.range_usize(1, 5);
            let gpus = r.range_usize(1, 5);
            let threshold = r.range_usize(1, 900);
            let k = r.range_usize(1, 4);
            let salt = r.next_u64();
            (machines, gpus, threshold, k, salt)
        },
        |&(machines, gpus, threshold, k, salt)| {
            let topo = Topology::new(machines, gpus);
            let mut lrng = Pcg64::with_stream(salt, 0x25C);
            let layout = random_layout(&mut lrng);
            let n = layout.total_len();
            let ranges = bucket_ranges(&build_buckets(&layout, threshold));
            let synth = ExactSynth { n, salt };
            let steps = 1;

            // spawn baseline (f32) is the reference
            let base = run_spawn_baseline(topo, n, threshold, &layout, k,
                                          steps, &synth);
            for wire in [WireFormat::F32, WireFormat::F16] {
                for overlap in [true, false] {
                    let tag =
                        format!("{topo} {wire:?} overlap={overlap} k={k}");
                    let rs = run_pool(
                        topo, n, ranges.clone(), wire,
                        CommMode::Hierarchical,
                        IntraNodeMode::ReduceScatter, overlap, k, steps,
                        &synth);
                    let serial = run_pool(
                        topo, n, ranges.clone(), wire,
                        CommMode::Hierarchical, IntraNodeMode::Serial,
                        overlap, k, steps, &synth);
                    let flat = run_pool(
                        topo, n, ranges.clone(), wire, CommMode::Flat,
                        IntraNodeMode::Auto, overlap, k, steps, &synth);
                    assert_bitwise(&format!("{tag} rs vs serial"), &rs,
                                   &serial)?;
                    assert_bitwise(&format!("{tag} rs vs flat"), &rs,
                                   &flat)?;
                    assert_bitwise(&format!("{tag} serial vs spawn"),
                                   &serial, &base)?;
                    // replicas identical within the rs mode
                    for r in 1..topo.world_size() {
                        if rs[0] != rs[r] {
                            return Err(format!(
                                "{tag}: rs replicas diverged (rank {r})"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn rs_corner_topologies_and_tiny_buckets_pinned() {
    // Pin the corners deterministically: g = 1 and m = 1 (rs falls back
    // to flat), the smallest true 2-level shape (2M2G), a wider node
    // (2M4G) — with a layout whose first bucket (3 elems) is SMALLER
    // than a 4-GPU node, so some shards and some cross-ring chunks are
    // empty.
    for (machines, gpus) in [(1usize, 1usize), (1, 4), (4, 1), (2, 2),
                             (2, 4)] {
        let topo = Topology::new(machines, gpus);
        let salt = 0x25EE_Du64 + (machines * 10 + gpus) as u64;
        let layout = ParamLayout::from_shapes(&[
            ("tiny".into(), vec![3]),
            ("a".into(), vec![301]),
            ("b".into(), vec![64]),
        ]);
        let n = layout.total_len();
        let threshold = 4; // "tiny" becomes its own 3-element bucket
        let ranges = bucket_ranges(&build_buckets(&layout, threshold));
        assert!(ranges.iter().any(|b| b.len() < 4),
                "fixture must include a bucket smaller than a node");
        let synth = ExactSynth { n, salt };
        let k = 2;
        let base =
            run_spawn_baseline(topo, n, threshold, &layout, k, 1, &synth);
        for wire in [WireFormat::F32, WireFormat::F16] {
            let rs = run_pool(topo, n, ranges.clone(), wire,
                              CommMode::Hierarchical,
                              IntraNodeMode::ReduceScatter, true, k, 1,
                              &synth);
            let serial = run_pool(topo, n, ranges.clone(), wire,
                                  CommMode::Hierarchical,
                                  IntraNodeMode::Serial, true, k, 1,
                                  &synth);
            assert_bitwise(&format!("{topo} {wire:?} rs vs serial"), &rs,
                           &serial)
                .unwrap();
            assert_bitwise(&format!("{topo} {wire:?} serial vs spawn"),
                           &serial, &base)
                .unwrap();
        }
    }
}

// ---------------------------------------------------------------------------
// socket == in-proc for the rs schedule, bitwise
// ---------------------------------------------------------------------------

/// Fresh loopback TCP addresses: bind-to-:0 probes, then released for
/// the transports to claim.
fn probe_addrs(n: usize) -> Vec<String> {
    let listeners: Vec<std::net::TcpListener> = (0..n)
        .map(|_| std::net::TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    listeners
        .iter()
        .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
        .collect()
}

/// Run `steps` pooled exchanges with the world split over `nprocs`
/// socket transports (one thread standing in for each process) and
/// return every rank's reduced gradients in world order.
#[allow(clippy::too_many_arguments)]
fn socket_world_grads(topo: Topology, nprocs: usize, wire: WireFormat,
                      mode: CommMode, intra: IntraNodeMode, n: usize,
                      ranges: &Arc<[BucketRange]>, steps: usize, k: usize,
                      salt: u64) -> Vec<Vec<f32>> {
    let peers = probe_addrs(nprocs);
    let world = topo.world_size();
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); world];
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..nprocs)
            .map(|p| {
                let peers = peers.clone();
                let ranges = ranges.clone();
                scope.spawn(move || {
                    let mut t = SocketTransport::with_hosts(
                        world, &peers[p], peers.clone(), 30.0).unwrap();
                    let mut pool = CollectivePool::with_transport(
                        topo, n, ranges, wire, mode, intra, 1 << 16,
                        Sparsify::None, &mut t).unwrap();
                    for s in 0..steps {
                        pool.step(&[], 1.0, k, s, true,
                                  &ExactSynth { n, salt })
                            .unwrap();
                    }
                    pool.local_ranks()
                        .map(|r| pool.rank_grads(r).clone())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for (p, h) in handles.into_iter().enumerate() {
            let grads = h.join().expect("socket world thread panicked");
            let per = world / nprocs;
            for (i, g) in grads.into_iter().enumerate() {
                out[p * per + i] = g;
            }
        }
    });
    out
}

#[test]
fn rs_socket_exchange_matches_inproc_bitwise() {
    // 2M2G split machine-per-process: the intra-node rings stay
    // in-memory inside each process, the per-shard cross-machine rings
    // travel the sockets — and the reduced bits must not care.
    let topo = Topology::new(2, 2);
    let salt = 0x5_0C4E7u64;
    let layout = ParamLayout::from_shapes(&[
        ("a".into(), vec![130]),
        ("b".into(), vec![77]),
    ]);
    let n = layout.total_len();
    let ranges = bucket_ranges(&build_buckets(&layout, 64));
    for wire in [WireFormat::F32, WireFormat::F16] {
        let sock = socket_world_grads(topo, 2, wire, CommMode::Hierarchical,
                                      IntraNodeMode::ReduceScatter, n,
                                      &ranges, 2, 2, salt);
        let inproc = run_pool(topo, n, ranges.clone(), wire,
                              CommMode::Hierarchical,
                              IntraNodeMode::ReduceScatter, true, 2, 2,
                              &ExactSynth { n, salt });
        assert_bitwise(&format!("rs socket vs inproc {wire:?}"), &sock,
                       &inproc)
            .unwrap();
    }
}

// ---------------------------------------------------------------------------
// loud-fail regressions: tampered frames surface named protocol errors
// ---------------------------------------------------------------------------

/// Wraps another transport and tampers with every frame sent on links
/// of one [`LinkKind`] — a stand-in for the desynchronized/buggy peer
/// the ISSUE-9 protocol checks must catch.
struct TamperTransport<T: Transport> {
    inner: T,
    kind: LinkKind,
    mutate: fn(&mut Frame),
}

struct TamperTx {
    inner: Box<dyn FrameTx>,
    mutate: fn(&mut Frame),
}

impl FrameTx for TamperTx {
    fn send(&mut self, mut frame: Frame, pool: &mut PayloadPool)
            -> Result<(), TransportError> {
        (self.mutate)(&mut frame);
        self.inner.send(frame, pool)
    }

    fn remote(&self) -> bool {
        self.inner.remote()
    }

    fn take_backpressure_s(&mut self) -> f64 {
        self.inner.take_backpressure_s()
    }
}

impl<T: Transport> Transport for TamperTransport<T> {
    fn world(&self) -> usize {
        self.inner.world()
    }

    fn local_ranks(&self) -> Range<usize> {
        self.inner.local_ranks()
    }

    fn link(&mut self, id: LinkId) -> Result<LinkEnds, TransportError> {
        let mut ends = self.inner.link(id)?;
        if id.kind == self.kind {
            if let Some(tx) = ends.tx.take() {
                ends.tx = Some(Box::new(TamperTx {
                    inner: tx,
                    mutate: self.mutate,
                }));
            }
        }
        Ok(ends)
    }
}

fn truncate_ring(f: &mut Frame) {
    match f {
        Frame::RingF32 { data, .. } => {
            data.pop();
        }
        Frame::RingF16 { data, .. } => {
            data.pop();
        }
        _ => {}
    }
}

fn skew_bucket(f: &mut Frame) {
    if let Frame::Bucket { idx, .. } = f {
        *idx += 1;
    }
}

fn truncate_bucket(f: &mut Frame) {
    if let Frame::Bucket { data, .. } = f {
        data.pop();
    }
}

fn skew_chunk(f: &mut Frame) {
    if let Frame::Chunk { chunk, .. } = f {
        *chunk += 1;
    }
}

fn skew_bcast(f: &mut Frame) {
    if let Frame::Bcast { idx, .. } = f {
        *idx += 1;
    }
}

/// One pooled step over an in-proc world whose `kind` links tamper with
/// every frame; returns the step error's full message.
fn tampered_step_err(topo: Topology, wire: WireFormat, mode: CommMode,
                     intra: IntraNodeMode, kind: LinkKind,
                     mutate: fn(&mut Frame)) -> String {
    let world = topo.world_size();
    let mut t = TamperTransport {
        inner: InProcTransport::new(world),
        kind,
        mutate,
    };
    let n = 96;
    let ranges = BucketRange::even_split(n, 2);
    let mut pool = CollectivePool::with_transport(
        topo, n, ranges, wire, mode, intra, 1 << 16, Sparsify::None,
        &mut t).unwrap();
    let err = pool
        .step(&[], 1.0, 1, 0, true, &ExactSynth { n, salt: 1 })
        .map(|_| ())
        .unwrap_err();
    format!("{err:#}")
}

#[test]
fn truncated_ring_payload_fails_loudly_f32_and_f16() {
    // Pre-fix, the recv_apply add-path `zip` silently dropped the tail
    // of a short ring payload (and the copy path panicked).
    for wire in [WireFormat::F32, WireFormat::F16] {
        let msg = tampered_step_err(Topology::new(2, 1), wire,
                                    CommMode::Flat, IntraNodeMode::Auto,
                                    LinkKind::FlatRing, truncate_ring);
        assert!(msg.contains("ring payload length skew"),
                "{wire:?}: {msg}");
        assert!(msg.contains("pooled step 0 failed"), "{wire:?}: {msg}");
    }
}

#[test]
fn skewed_member_bucket_fails_loudly_in_release() {
    // Pre-fix this was a debug_assert: a release build summed the WRONG
    // bucket's data silently.
    let msg = tampered_step_err(Topology::new(2, 2), WireFormat::F32,
                                CommMode::Hierarchical,
                                IntraNodeMode::Serial, LinkKind::MemberUp,
                                skew_bucket);
    assert!(msg.contains("member bucket skew"), "{msg}");
}

#[test]
fn short_member_payload_fails_loudly() {
    let msg = tampered_step_err(Topology::new(2, 2), WireFormat::F32,
                                CommMode::Hierarchical,
                                IntraNodeMode::Serial, LinkKind::MemberUp,
                                truncate_bucket);
    assert!(msg.contains("member payload length skew"), "{msg}");
}

#[test]
fn skewed_chain_chunk_fails_loudly() {
    let msg = tampered_step_err(Topology::new(2, 2), WireFormat::F32,
                                CommMode::Hierarchical,
                                IntraNodeMode::Ring, LinkKind::ChainUp,
                                skew_chunk);
    assert!(msg.contains("chain chunk skew"), "{msg}");
}

#[test]
fn skewed_broadcast_fails_loudly() {
    let msg = tampered_step_err(Topology::new(2, 2), WireFormat::F32,
                                CommMode::Hierarchical,
                                IntraNodeMode::Serial,
                                LinkKind::MemberDown, skew_bcast);
    assert!(msg.contains("broadcast bucket skew"), "{msg}");
}

#[test]
fn rs_truncated_intra_and_cross_frames_fail_loudly() {
    // The new schedule inherits the hardened ring protocol on BOTH of
    // its levels.
    let intra_msg = tampered_step_err(Topology::new(2, 2), WireFormat::F32,
                                      CommMode::Hierarchical,
                                      IntraNodeMode::ReduceScatter,
                                      LinkKind::RsIntra, truncate_ring);
    assert!(intra_msg.contains("ring payload length skew"), "{intra_msg}");
    assert!(intra_msg.contains("intra reduce-scatter"), "{intra_msg}");
    let cross_msg = tampered_step_err(Topology::new(2, 2), WireFormat::F32,
                                      CommMode::Hierarchical,
                                      IntraNodeMode::ReduceScatter,
                                      LinkKind::RsCross, truncate_ring);
    assert!(cross_msg.contains("ring payload length skew"), "{cross_msg}");
    assert!(cross_msg.contains("cross ring"), "{cross_msg}");
}

/// Two socket processes where process `bad` tampers its `kind` sends;
/// returns (good process's step error, bad process's step error).
fn socket_tampered_errs(topo: Topology, mode: CommMode,
                        intra: IntraNodeMode, kind: LinkKind,
                        mutate: fn(&mut Frame)) -> (String, String) {
    let peers = probe_addrs(2);
    let world = topo.world_size();
    let n = 96;
    let ranges = BucketRange::even_split(n, 2);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|p| {
                let peers = peers.clone();
                let ranges = ranges.clone();
                scope.spawn(move || {
                    let mut sock = SocketTransport::with_hosts(
                        world, &peers[p], peers.clone(), 30.0).unwrap();
                    let err = if p == 0 {
                        let mut t = TamperTransport {
                            inner: sock,
                            kind,
                            mutate,
                        };
                        let mut pool = CollectivePool::with_transport(
                            topo, n, ranges, WireFormat::F32, mode, intra,
                            1 << 16, Sparsify::None, &mut t).unwrap();
                        pool.step(&[], 1.0, 1, 0, true,
                                  &ExactSynth { n, salt: 1 })
                            .map(|_| ())
                            .unwrap_err()
                    } else {
                        let mut pool = CollectivePool::with_transport(
                            topo, n, ranges, WireFormat::F32, mode, intra,
                            1 << 16, Sparsify::None, &mut sock).unwrap();
                        pool.step(&[], 1.0, 1, 0, true,
                                  &ExactSynth { n, salt: 1 })
                            .map(|_| ())
                            .unwrap_err()
                    };
                    format!("{err:#}")
                })
            })
            .collect();
        let mut msgs = handles
            .into_iter()
            .map(|h| h.join().expect("socket thread panicked"));
        let bad = msgs.next().unwrap();
        let good = msgs.next().unwrap();
        (good, bad)
    })
}

#[test]
fn truncated_ring_payload_fails_loudly_over_sockets() {
    // The tampering process hosts rank 0; its flat-ring frame crosses a
    // REAL socket, and the receiving process must name the corruption.
    let (good, _bad) = socket_tampered_errs(
        Topology::new(2, 1), CommMode::Flat, IntraNodeMode::Auto,
        LinkKind::FlatRing, truncate_ring);
    assert!(good.contains("ring payload length skew"), "{good}");
}

#[test]
fn rs_truncated_cross_frame_fails_loudly_over_sockets() {
    // 2M2G machine-per-process: the tampered rs cross-ring frames cross
    // the sockets; the peer machine's ranks must fail loudly.
    let (good, _bad) = socket_tampered_errs(
        Topology::new(2, 2), CommMode::Hierarchical,
        IntraNodeMode::ReduceScatter, LinkKind::RsCross, truncate_ring);
    assert!(good.contains("ring payload length skew"), "{good}");
    assert!(good.contains("cross ring"), "{good}");
}
