//! Golden numerics: the AOT HLO apply step vs the host Rust optimizer —
//! two independent implementations of LAMB must agree, proving the
//! manifest layout contract and the fused Pallas kernel semantics.

use std::path::PathBuf;

use bertdist::optimizer::{lamb_step, OptHyper, OptState};
use bertdist::runtime::Engine;
use bertdist::testkit;
use bertdist::trainer::init_params;
use bertdist::util::Pcg64;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

#[test]
fn hlo_lamb_matches_host_lamb() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let apply = engine.apply_step("bert-micro", "lamb").unwrap();
    let n = model.param_count;

    let mut rng = Pcg64::new(21);
    let params0 = init_params(&model.layout, &mut rng);
    let grads: Vec<f32> =
        (0..n).map(|_| (rng.next_gaussian() * 0.01) as f32).collect();

    // HLO path
    let mut p_hlo = params0.clone();
    let mut m_hlo = vec![0.0f32; n];
    let mut v_hlo = vec![0.0f32; n];
    apply.run(&mut p_hlo, &grads, &mut m_hlo, &mut v_hlo, 1.0, 1e-3)
        .unwrap();

    // host path (same math: clip 1.0, per-tensor trust, bias correction)
    let mut p_host = params0.clone();
    let mut g_host = grads.clone();
    let mut st = OptState::new(n);
    lamb_step(&mut p_host, &mut g_host, &mut st, &model.layout, 1e-3,
              &OptHyper::default());

    testkit::assert_allclose(&p_hlo, &p_host, 1e-5, 1e-3);
    testkit::assert_allclose(&m_hlo, &st.m, 1e-6, 1e-3);
    testkit::assert_allclose(&v_hlo, &st.v, 1e-7, 1e-3);
}

#[test]
fn hlo_lamb_second_step_matches_host() {
    // bias correction uses the step counter — verify step 2 too.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let apply = engine.apply_step("bert-micro", "lamb").unwrap();
    let n = model.param_count;
    let mut rng = Pcg64::new(22);
    let params0 = init_params(&model.layout, &mut rng);
    let g1: Vec<f32> = (0..n).map(|_| (rng.next_gaussian() * 0.01) as f32)
        .collect();
    let g2: Vec<f32> = (0..n).map(|_| (rng.next_gaussian() * 0.02) as f32)
        .collect();

    let mut p_hlo = params0.clone();
    let mut m_hlo = vec![0.0f32; n];
    let mut v_hlo = vec![0.0f32; n];
    apply.run(&mut p_hlo, &g1, &mut m_hlo, &mut v_hlo, 1.0, 1e-3).unwrap();
    apply.run(&mut p_hlo, &g2, &mut m_hlo, &mut v_hlo, 2.0, 1e-3).unwrap();

    let mut p_host = params0;
    let mut st = OptState::new(n);
    let h = OptHyper::default();
    lamb_step(&mut p_host, &mut g1.clone(), &mut st, &model.layout, 1e-3, &h);
    lamb_step(&mut p_host, &mut g2.clone(), &mut st, &model.layout, 1e-3, &h);

    testkit::assert_allclose(&p_hlo, &p_host, 1e-5, 2e-3);
}

#[test]
fn hlo_adam_differs_from_lamb_direction() {
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let n = model.param_count;
    let mut rng = Pcg64::new(23);
    let params0 = init_params(&model.layout, &mut rng);
    let grads: Vec<f32> = (0..n).map(|_| (rng.next_gaussian() * 0.01) as f32)
        .collect();

    let run = |opt: &str| {
        let apply = engine.apply_step("bert-micro", opt).unwrap();
        let mut p = params0.clone();
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        apply.run(&mut p, &grads, &mut m, &mut v, 1.0, 1e-3).unwrap();
        p
    };
    let p_lamb = run("lamb");
    let p_adam = run("adam");
    let diff: f32 = p_lamb.iter().zip(&p_adam)
        .map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-3, "lamb and adam should differ: {diff}");
}

#[test]
fn train_step_loss_scale_invariance_through_hlo() {
    // §4.2 at the artifact level: scaled and unscaled gradients agree
    // after unscaling (the HLO does the divide internally).
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use bertdist::data::masking::{build_batch, MaskingConfig};
    use bertdist::data::PairExample;

    let engine = Engine::cpu(&art).unwrap();
    let model = engine.model("bert-micro").unwrap();
    let step = engine.train_step("bert-micro", "fused_f32", 2, 32).unwrap();
    let mut rng = Pcg64::new(24);
    let params = init_params(&model.layout, &mut rng);
    let ex = PairExample {
        tokens_a: (10..20).collect(),
        tokens_b: (30..44).collect(),
        is_next: true,
    };
    let cfg = MaskingConfig { vocab_size: 512, ..Default::default() };
    let batch = build_batch(&[ex.clone(), ex], 32, &cfg, &mut rng);

    let g1 = step.run(&params, &batch, 1.0).unwrap();
    let g1024 = step.run(&params, &batch, 1024.0).unwrap();
    assert!((g1.loss - g1024.loss).abs() < 1e-4,
            "reported loss must be unscaled");
    testkit::assert_allclose(&g1.grads, &g1024.grads, 1e-6, 1e-3);
}
