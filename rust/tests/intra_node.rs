//! ISSUE 5 (tentpole): chunked pipelined intra-node exchange.
//!
//! The headline property: across random `<X>M<Y>G` topologies
//! (including the `g = 1` / `m = 1` degenerates), random bucket
//! thresholds, accumulation depths, and chunk sizes (including 1
//! element and chunk > bucket), both overlap modes and both wire
//! formats, the **pipelined-ring** intra-node exchange, the
//! **serialized-leader** schedule, and the old **spawn-per-step
//! baseline** all produce bitwise-identical reduced gradients on
//! exact-sum gradients (dyadic grid, so no summation association can
//! matter) — and every replica within a mode is bitwise identical.
//!
//! Plus: chunk accounting (`chunks_per_bucket`), the timing split
//! staying consistent under the chunk pipeline, and rounding-tolerance
//! agreement on arbitrary floats.

use std::sync::Arc;

use bertdist::collectives::pool::{CollectivePool, CommMode, IntraNodeMode,
                                  MicroStats, RankCompute, WireFormat};
use bertdist::grad::{bucket_ranges, build_buckets, BucketRange,
                     GradAccumulator};
use bertdist::metrics::ExchangeTimings;
use bertdist::model::layout::ParamLayout;
use bertdist::testkit;
use bertdist::topology::Topology;
use bertdist::trainer::allreduce_buckets;
use bertdist::util::Pcg64;

/// Deterministic synthetic gradients on a dyadic grid: multiples of
/// 0.25 in [-2, 2].  With at most 4x4 ranks and 3 micro-steps, every
/// partial sum under ANY association is exactly representable in both
/// f32 and f16 — so the chain, the serialized leader, the flat ring,
/// and the spawn baseline must all agree to the bit.
struct ExactSynth {
    n: usize,
    salt: u64,
}

impl RankCompute for ExactSynth {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             _params: &[f32], _scale: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        let stream = (rank as u64) << 32
            | (step_index as u64) << 8
            | micro as u64;
        let mut rng = Pcg64::with_stream(self.salt, stream);
        for v in out.iter_mut() {
            *v = (rng.range_usize(0, 17) as f32 - 8.0) * 0.25;
        }
        Ok(MicroStats { loss: 1.0, ..Default::default() })
    }
}

/// Arbitrary-float variant (association differences show up as
/// rounding, never as divergence).
struct Synth {
    n: usize,
    salt: u64,
}

impl RankCompute for Synth {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             _params: &[f32], _scale: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        let stream = (rank as u64) << 32
            | (step_index as u64) << 8
            | micro as u64;
        let mut rng = Pcg64::with_stream(self.salt, stream);
        for v in out.iter_mut() {
            *v = rng.next_f32() * 4.0 - 2.0;
        }
        Ok(MicroStats { loss: 1.0, ..Default::default() })
    }
}

fn random_layout(rng: &mut Pcg64) -> ParamLayout {
    let tensors = rng.range_usize(1, 10);
    let shapes: Vec<(String, Vec<usize>)> = (0..tensors)
        .map(|i| (format!("t{i}"), vec![rng.range_usize(1, 400)]))
        .collect();
    ParamLayout::from_shapes(&shapes)
}

/// Run `steps` pooled steps under (mode, intra, chunk) and return every
/// rank's reduced buffer plus the accumulated timings.
#[allow(clippy::too_many_arguments)]
fn run_pool(topo: Topology, n: usize, ranges: Arc<[BucketRange]>,
            wire: WireFormat, intra: IntraNodeMode, chunk: usize,
            overlap: bool, k: usize, steps: usize,
            compute: &dyn RankCompute)
            -> (Vec<Vec<f32>>, ExchangeTimings) {
    let mut pool = CollectivePool::with_intra(
        topo, n, ranges, wire, CommMode::Hierarchical, intra, chunk);
    let mut timings = ExchangeTimings {
        bucket_chunks: pool.chunks_per_bucket(),
        ..Default::default()
    };
    for s in 0..steps {
        let out = pool.step(&[], 1.0, k, s, overlap, compute).unwrap();
        assert!(out.exposed_comm_s >= 0.0);
        assert!(out.comm_net_s <= out.comm_s + 1e-9,
                "net {} > total {}", out.comm_net_s, out.comm_s);
        timings.record(&out.bucket_s, &out.bucket_pcie_s,
                       &out.bucket_net_s, out.exposed_comm_s);
    }
    let grads = (0..topo.world_size())
        .map(|r| pool.rank_grads(r).clone())
        .collect();
    (grads, timings)
}

/// The old spawn-per-step exchange over the same gradients (f32 only).
fn run_spawn_baseline(topo: Topology, n: usize, threshold: usize,
                      layout: &ParamLayout, k: usize, steps: usize,
                      compute: &dyn RankCompute) -> Vec<Vec<f32>> {
    let world = topo.world_size();
    let buckets = build_buckets(layout, threshold);
    let mut accs: Vec<GradAccumulator> =
        (0..world).map(|_| GradAccumulator::new(n)).collect();
    let mut g = Vec::new();
    for s in 0..steps {
        for (r, acc) in accs.iter_mut().enumerate() {
            acc.reset();
            for m in 0..k {
                compute.micro(r, s, m, &[], 1.0, &mut g).unwrap();
                acc.add(&g);
            }
        }
        allreduce_buckets(&mut accs, &buckets);
    }
    accs.iter().map(|a| a.buffer().to_vec()).collect()
}

fn assert_bitwise(tag: &str, a: &[Vec<f32>], b: &[Vec<f32>])
                  -> Result<(), String> {
    for (r, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        for (i, (va, vb)) in x.iter().zip(y.iter()).enumerate() {
            if va.to_bits() != vb.to_bits() {
                return Err(format!("{tag}: rank {r} [{i}]: {va} != {vb}"));
            }
        }
    }
    Ok(())
}

#[test]
fn prop_pipelined_serialized_and_spawn_baseline_bitwise_identical() {
    testkit::check_msg(
        "intra-ring≡serial≡spawn", 0x1A7_2A, 8,
        |r: &mut Pcg64| {
            let machines = r.range_usize(1, 5);
            let gpus = r.range_usize(1, 5);
            let threshold = r.range_usize(1, 900);
            // chunk sizes spanning the degenerates: single-element
            // chunks, mid-size, and chunk > any bucket
            let chunk = [1usize, 13, 100, 1_000_000]
                [r.range_usize(0, 4)];
            let k = r.range_usize(1, 4);
            let salt = r.next_u64();
            (machines, gpus, threshold, chunk, k, salt)
        },
        |&(machines, gpus, threshold, chunk, k, salt)| {
            let topo = Topology::new(machines, gpus);
            let mut lrng = Pcg64::with_stream(salt, 0x1A7);
            let layout = random_layout(&mut lrng);
            let n = layout.total_len();
            let ranges = bucket_ranges(&build_buckets(&layout, threshold));
            let steps = 1;
            let synth = ExactSynth { n, salt };

            // spawn baseline (f32) is the reference
            let base = run_spawn_baseline(topo, n, threshold, &layout, k,
                                          steps, &synth);
            for wire in [WireFormat::F32, WireFormat::F16] {
                for overlap in [true, false] {
                    let tag = format!(
                        "{topo} {wire:?} chunk={chunk} overlap={overlap} \
                         k={k}");
                    let (serial, _) = run_pool(
                        topo, n, ranges.clone(), wire,
                        IntraNodeMode::Serial, chunk, overlap, k, steps,
                        &synth);
                    let (ring, ring_t) = run_pool(
                        topo, n, ranges.clone(), wire, IntraNodeMode::Ring,
                        chunk, overlap, k, steps, &synth);
                    assert_bitwise(&format!("{tag} ring vs serial"), &ring,
                                   &serial)?;
                    assert_bitwise(&format!("{tag} serial vs spawn"),
                                   &serial, &base)?;
                    // replicas identical within the pipelined mode
                    for r in 1..topo.world_size() {
                        if ring[0] != ring[r] {
                            return Err(format!(
                                "{tag}: replicas diverged (rank {r})"));
                        }
                    }
                    // the chunk pipeline keeps the overlap ratio a
                    // true fraction
                    let e = ring_t.overlap_efficiency();
                    if !(0.0..=1.0).contains(&e) {
                        return Err(format!(
                            "{tag}: overlap efficiency {e} not in [0,1]"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn degenerate_topologies_and_chunks_pinned() {
    // Pin the corners deterministically: g = 1 (no members — the
    // hierarchy never resolves, chain irrelevant), m = 1 (flat
    // fallback), 1x1, the smallest true chain (2M2G), a deeper chain
    // (2M4G); chunk sizes 1 and far-larger-than-bucket.
    for (machines, gpus) in [(1usize, 1usize), (1, 4), (4, 1), (2, 2),
                             (2, 4)] {
        let topo = Topology::new(machines, gpus);
        let salt = 0x5EED_0u64 + (machines * 10 + gpus) as u64;
        let layout = ParamLayout::from_shapes(&[
            ("a".into(), vec![37]),
            ("b".into(), vec![301]),
            ("c".into(), vec![64]),
        ]);
        let n = layout.total_len();
        let threshold = 128;
        let ranges = bucket_ranges(&build_buckets(&layout, threshold));
        let synth = ExactSynth { n, salt };
        let k = 2;
        let base =
            run_spawn_baseline(topo, n, threshold, &layout, k, 1, &synth);
        for chunk in [1usize, 50, 100_000] {
            for wire in [WireFormat::F32, WireFormat::F16] {
                let (serial, _) = run_pool(topo, n, ranges.clone(), wire,
                                           IntraNodeMode::Serial, chunk,
                                           true, k, 1, &synth);
                let (ring, _) = run_pool(topo, n, ranges.clone(), wire,
                                         IntraNodeMode::Ring, chunk, true,
                                         k, 1, &synth);
                assert_bitwise(&format!("{topo} {wire:?} chunk={chunk} \
                                         ring vs serial"),
                               &ring, &serial)
                    .unwrap();
                assert_bitwise(&format!("{topo} {wire:?} chunk={chunk} \
                                         serial vs spawn"),
                               &serial, &base)
                    .unwrap();
            }
        }
    }
}

#[test]
fn chunk_accounting_matches_the_bucket_table() {
    let topo = Topology::new(2, 3);
    let layout = ParamLayout::from_shapes(&[
        ("a".into(), vec![100]),
        ("b".into(), vec![57]),
    ]);
    let n = layout.total_len();
    let ranges = bucket_ranges(&build_buckets(&layout, 64));
    let pool = CollectivePool::with_intra(
        topo, n, ranges.clone(), WireFormat::F32, CommMode::Auto,
        IntraNodeMode::Ring, 30);
    assert!(pool.is_intra_ring());
    let chunks = pool.chunks_per_bucket();
    assert_eq!(chunks.len(), ranges.len());
    for (c, b) in chunks.iter().zip(ranges.iter()) {
        assert_eq!(*c, (b.len() + 29) / 30, "bucket len {}", b.len());
        assert!(*c >= 1);
    }
    // chunk > every bucket: one chunk each (the serialized granularity)
    let one = CollectivePool::with_intra(
        topo, n, ranges, WireFormat::F32, CommMode::Auto,
        IntraNodeMode::Ring, 1_000_000);
    assert!(one.chunks_per_bucket().iter().all(|&c| c == 1));
}

#[test]
fn pipelined_matches_serial_within_rounding_on_arbitrary_floats() {
    // On general floats the chain (tail-to-head) and the serialized
    // leader (head-to-tail) associate the node sum differently, so
    // require tolerance-equality; bitwise is covered on the exact grid.
    let topo = Topology::new(2, 4);
    let (n, k, salt) = (901usize, 2usize, 0xF1A7u64);
    let layout = ParamLayout::from_shapes(&[("a".into(), vec![n])]);
    let ranges = bucket_ranges(&build_buckets(&layout, 200));
    let synth = Synth { n, salt };
    let (serial, _) = run_pool(topo, n, ranges.clone(), WireFormat::F32,
                               IntraNodeMode::Serial, 64, true, k, 1,
                               &synth);
    let (ring, timings) = run_pool(topo, n, ranges, WireFormat::F32,
                                   IntraNodeMode::Ring, 64, true, k, 1,
                                   &synth);
    for r in 0..topo.world_size() {
        testkit::assert_allclose(&ring[r], &serial[r], 1e-3, 1e-4);
    }
    // the chunked timings still render a coherent per-chunk timeline
    let tl = timings.to_timeline();
    assert!(tl.spans.iter().any(|s| s.name.contains(".c0")),
            "expected per-chunk spans, got {:?}",
            tl.spans.iter().map(|s| &s.name).collect::<Vec<_>>());
}
