//! ISSUE 10 (tentpole): top-k gradient sparsification on the
//! network-crossing rings (`train.sparsify = topk:RATIO`), with the
//! bitwise/convergence test wall.
//!
//! The headline property: across random `<X>M<Y>G` topologies, all
//! three comm schedules (flat world ring, serialized leader,
//! 2-level reduce-scatter) and both wire formats, `topk:1.0` produces
//! gradients BITWISE identical to the dense exchange — and to the old
//! spawn-per-step baseline — on exact-sum gradients (dyadic grid, so
//! no summation association can matter).  Full-ratio sparsification
//! changes the framing, never the bits.
//!
//! Below 1.0 the exchange is lossy but still deterministic: the same
//! seed gives identical parameters over `InProcTransport` and
//! `SocketTransport`, and across `train.prefetch_depth` 0 and 2; the
//! error-feedback residual snapshot/restore round-trips bitwise
//! (pool-level resume).
//!
//! Plus the loud-fail regressions: a peer that ships a truncated
//! sparse payload, an out-of-bounds index, skewed index/value lengths,
//! a skewed dense dimension, a skewed schedule tag, or the wrong frame
//! kind on a sparse ring link surfaces a NAMED protocol error — on
//! both transports, in release builds — instead of silently
//! scattering garbage into the gradient sum.

use std::ops::Range;
use std::path::PathBuf;
use std::sync::Arc;

use bertdist::collectives::pool::{CollectivePool, CommMode, IntraNodeMode,
                                  MicroStats, RankCompute, WireFormat};
use bertdist::collectives::transport::{FrameTx, InProcTransport, LinkEnds,
                                       LinkId, LinkKind, PayloadPool,
                                       Transport, TransportError};
use bertdist::collectives::{Frame, SocketTransport};
use bertdist::grad::sparsify::Sparsify;
use bertdist::grad::{bucket_ranges, build_buckets, BucketRange,
                     GradAccumulator};
use bertdist::model::layout::ParamLayout;
use bertdist::testkit;
use bertdist::topology::Topology;
use bertdist::trainer::allreduce_buckets;
use bertdist::util::Pcg64;

// ---------------------------------------------------------------------------
// shared fixtures (the exchange_rs.rs dyadic-grid idiom)
// ---------------------------------------------------------------------------

/// Deterministic synthetic gradients on a dyadic grid: multiples of
/// 0.25 in [-2, 2].  Every partial sum under ANY association is exactly
/// representable in both f32 and f16, so the `topk:1.0` allgather
/// reconstruction (fixed origin order) and the dense ring
/// reduce-scatter (ring order) must agree to the bit.
struct ExactSynth {
    n: usize,
    salt: u64,
}

impl RankCompute for ExactSynth {
    fn micro(&self, rank: usize, step_index: usize, micro: usize,
             _params: &[f32], _scale: f32, out: &mut Vec<f32>)
             -> anyhow::Result<MicroStats> {
        out.resize(self.n, 0.0);
        let stream = (rank as u64) << 32
            | (step_index as u64) << 8
            | micro as u64;
        let mut rng = Pcg64::with_stream(self.salt, stream);
        for v in out.iter_mut() {
            *v = (rng.range_usize(0, 17) as f32 - 8.0) * 0.25;
        }
        Ok(MicroStats { loss: 1.0, ..Default::default() })
    }
}

fn random_layout(rng: &mut Pcg64) -> ParamLayout {
    let tensors = rng.range_usize(1, 10);
    let shapes: Vec<(String, Vec<usize>)> = (0..tensors)
        .map(|i| (format!("t{i}"), vec![rng.range_usize(1, 400)]))
        .collect();
    ParamLayout::from_shapes(&shapes)
}

/// Run `steps` pooled steps under (mode, intra, sparsify) over an
/// in-process transport and return every rank's reduced buffer.
#[allow(clippy::too_many_arguments)]
fn run_pool(topo: Topology, n: usize, ranges: Arc<[BucketRange]>,
            wire: WireFormat, mode: CommMode, intra: IntraNodeMode,
            overlap: bool, k: usize, steps: usize, sparsify: Sparsify,
            compute: &dyn RankCompute) -> Vec<Vec<f32>> {
    let world = topo.world_size();
    let mut t = InProcTransport::new(world);
    let mut pool = CollectivePool::with_transport(
        topo, n, ranges, wire, mode, intra, 1 << 16, sparsify, &mut t)
        .unwrap();
    for s in 0..steps {
        pool.step(&[], 1.0, k, s, overlap, compute).unwrap();
    }
    (0..world).map(|r| pool.rank_grads(r).clone()).collect()
}

/// The old spawn-per-step exchange over the same gradients (f32 only).
fn run_spawn_baseline(topo: Topology, n: usize, threshold: usize,
                      layout: &ParamLayout, k: usize, steps: usize,
                      compute: &dyn RankCompute) -> Vec<Vec<f32>> {
    let world = topo.world_size();
    let buckets = build_buckets(layout, threshold);
    let mut accs: Vec<GradAccumulator> =
        (0..world).map(|_| GradAccumulator::new(n)).collect();
    let mut g = Vec::new();
    for s in 0..steps {
        for (r, acc) in accs.iter_mut().enumerate() {
            acc.reset();
            for m in 0..k {
                compute.micro(r, s, m, &[], 1.0, &mut g).unwrap();
                acc.add(&g);
            }
        }
        allreduce_buckets(&mut accs, &buckets);
    }
    accs.iter().map(|a| a.buffer().to_vec()).collect()
}

fn assert_bitwise(tag: &str, a: &[Vec<f32>], b: &[Vec<f32>])
                  -> Result<(), String> {
    for (r, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if x.len() != y.len() {
            return Err(format!("{tag}: rank {r} length {} != {}",
                               x.len(), y.len()));
        }
        for (i, (va, vb)) in x.iter().zip(y.iter()).enumerate() {
            if va.to_bits() != vb.to_bits() {
                return Err(format!("{tag}: rank {r} [{i}]: {va} != {vb}"));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// the headline property: topk:1.0 ≡ dense ≡ spawn baseline, bitwise
// ---------------------------------------------------------------------------

#[test]
fn prop_topk_full_ratio_matches_dense_and_spawn_bitwise() {
    testkit::check_msg(
        "topk(1.0)≡dense≡spawn", 0x5A12, 8,
        |r: &mut Pcg64| {
            let machines = r.range_usize(1, 5);
            let gpus = r.range_usize(1, 4);
            let threshold = r.range_usize(1, 900);
            let k = r.range_usize(1, 4);
            let overlap = r.range_usize(0, 2) == 1;
            let salt = r.next_u64();
            (machines, gpus, threshold, k, overlap, salt)
        },
        |&(machines, gpus, threshold, k, overlap, salt)| {
            let topo = Topology::new(machines, gpus);
            let mut lrng = Pcg64::with_stream(salt, 0x5A1);
            let layout = random_layout(&mut lrng);
            let n = layout.total_len();
            let ranges = bucket_ranges(&build_buckets(&layout, threshold));
            let synth = ExactSynth { n, salt };
            // two steps: at ratio 1.0 the error-feedback residual must
            // stay exactly zero, so step 2 re-proves it rides along as
            // a no-op rather than once-by-luck
            let steps = 2;

            let base = run_spawn_baseline(topo, n, threshold, &layout, k,
                                          steps, &synth);
            for wire in [WireFormat::F32, WireFormat::F16] {
                for (mode, intra) in
                    [(CommMode::Flat, IntraNodeMode::Auto),
                     (CommMode::Hierarchical, IntraNodeMode::Serial),
                     (CommMode::Hierarchical, IntraNodeMode::ReduceScatter)]
                {
                    let tag = format!(
                        "{topo} {wire:?} {mode:?}/{intra:?} \
                         overlap={overlap} k={k}");
                    let dense = run_pool(
                        topo, n, ranges.clone(), wire, mode, intra,
                        overlap, k, steps, Sparsify::None, &synth);
                    let sparse = run_pool(
                        topo, n, ranges.clone(), wire, mode, intra,
                        overlap, k, steps, Sparsify::TopK(1.0), &synth);
                    assert_bitwise(&format!("{tag} topk(1.0) vs dense"),
                                   &sparse, &dense)?;
                    if wire == WireFormat::F32 {
                        assert_bitwise(&format!("{tag} dense vs spawn"),
                                       &dense, &base)?;
                    }
                    // replicas identical within the sparse run
                    for r in 1..topo.world_size() {
                        if sparse[0] != sparse[r] {
                            return Err(format!(
                                "{tag}: sparse replicas diverged \
                                 (rank {r})"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn single_machine_topk_is_inert_and_bitwise_dense() {
    // Placement is a pure function of the TOPOLOGY: one machine has no
    // network ring, so even an aggressive ratio changes nothing — no
    // residuals are allocated and the grads match dense to the bit.
    let topo = Topology::new(1, 4);
    let layout = ParamLayout::from_shapes(&[
        ("a".into(), vec![211]),
        ("b".into(), vec![96]),
    ]);
    let n = layout.total_len();
    let ranges = bucket_ranges(&build_buckets(&layout, 128));
    let synth = ExactSynth { n, salt: 0x1E47 };
    let dense = run_pool(topo, n, ranges.clone(), WireFormat::F32,
                         CommMode::Flat, IntraNodeMode::Auto, true, 2, 2,
                         Sparsify::None, &synth);
    let mut t = InProcTransport::new(topo.world_size());
    let mut pool = CollectivePool::with_transport(
        topo, n, ranges, WireFormat::F32, CommMode::Flat,
        IntraNodeMode::Auto, 1 << 16, Sparsify::TopK(0.01), &mut t)
        .unwrap();
    assert_eq!(pool.sparsify(), Sparsify::TopK(0.01));
    assert!(!pool.sparsify_active(), "1M topology must leave topk inert");
    assert!(pool.ef_snapshot().is_empty(),
            "inert sparsify must not allocate residuals");
    for s in 0..2 {
        pool.step(&[], 1.0, 2, s, true, &synth).unwrap();
    }
    let sparse: Vec<Vec<f32>> = (0..topo.world_size())
        .map(|r| pool.rank_grads(r).clone())
        .collect();
    assert_bitwise("inert topk vs dense", &sparse, &dense).unwrap();
}

// ---------------------------------------------------------------------------
// ratio < 1.0: lossy but deterministic, and EF state resumes bitwise
// ---------------------------------------------------------------------------

/// Fresh loopback TCP addresses: bind-to-:0 probes, then released for
/// the transports to claim.
fn probe_addrs(n: usize) -> Vec<String> {
    let listeners: Vec<std::net::TcpListener> = (0..n)
        .map(|_| std::net::TcpListener::bind("127.0.0.1:0").unwrap())
        .collect();
    listeners
        .iter()
        .map(|l| format!("127.0.0.1:{}", l.local_addr().unwrap().port()))
        .collect()
}

/// Run `steps` pooled exchanges with the world split over `nprocs`
/// socket transports and return every rank's reduced gradients in
/// world order.
#[allow(clippy::too_many_arguments)]
fn socket_world_grads(topo: Topology, nprocs: usize, wire: WireFormat,
                      mode: CommMode, intra: IntraNodeMode, n: usize,
                      ranges: &Arc<[BucketRange]>, steps: usize, k: usize,
                      sparsify: Sparsify, salt: u64) -> Vec<Vec<f32>> {
    let peers = probe_addrs(nprocs);
    let world = topo.world_size();
    let mut out: Vec<Vec<f32>> = vec![Vec::new(); world];
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..nprocs)
            .map(|p| {
                let peers = peers.clone();
                let ranges = ranges.clone();
                scope.spawn(move || {
                    let mut t = SocketTransport::with_hosts(
                        world, &peers[p], peers.clone(), 30.0).unwrap();
                    let mut pool = CollectivePool::with_transport(
                        topo, n, ranges, wire, mode, intra, 1 << 16,
                        sparsify, &mut t).unwrap();
                    for s in 0..steps {
                        pool.step(&[], 1.0, k, s, true,
                                  &ExactSynth { n, salt })
                            .unwrap();
                    }
                    pool.local_ranks()
                        .map(|r| pool.rank_grads(r).clone())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for (p, h) in handles.into_iter().enumerate() {
            let grads = h.join().expect("socket world thread panicked");
            let per = world / nprocs;
            for (i, g) in grads.into_iter().enumerate() {
                out[p * per + i] = g;
            }
        }
    });
    out
}

#[test]
fn topk_below_one_is_deterministic_across_transports() {
    // Lossy ratios drop real mass into the residual, so three steps
    // exercise the error feedback riding between steps — and the
    // resulting bits must not care whether the sparse frames moved
    // in-memory or over real sockets.
    let topo = Topology::new(2, 2);
    let salt = 0x70_0B17u64;
    let layout = ParamLayout::from_shapes(&[
        ("a".into(), vec![130]),
        ("b".into(), vec![77]),
    ]);
    let n = layout.total_len();
    let ranges = bucket_ranges(&build_buckets(&layout, 64));
    for (mode, intra) in
        [(CommMode::Flat, IntraNodeMode::Auto),
         (CommMode::Hierarchical, IntraNodeMode::Serial),
         (CommMode::Hierarchical, IntraNodeMode::ReduceScatter)]
    {
        for wire in [WireFormat::F32, WireFormat::F16] {
            let tag = format!("{mode:?}/{intra:?} {wire:?}");
            let sock = socket_world_grads(
                topo, 2, wire, mode, intra, n, &ranges, 3, 2,
                Sparsify::TopK(0.1), salt);
            let inproc = run_pool(
                topo, n, ranges.clone(), wire, mode, intra, true, 2, 3,
                Sparsify::TopK(0.1), &ExactSynth { n, salt });
            assert_bitwise(&format!("topk(0.1) socket vs inproc {tag}"),
                           &sock, &inproc)
                .unwrap();
            // the lossy exchange still keeps every replica identical
            for r in 1..topo.world_size() {
                assert_bitwise(&format!("{tag} replica {r}"),
                               &[inproc[0].clone()],
                               &[inproc[r].clone()])
                    .unwrap();
            }
        }
    }
}

#[test]
fn ef_snapshot_restore_resumes_the_sparse_stream_bitwise() {
    // Pool-level resume: 4 uninterrupted lossy steps vs 2 steps +
    // ef_snapshot + a FRESH pool restored from the snapshot finishing
    // steps 2..4.  The reduced gradients after the final step must
    // match bitwise — the residual is the only cross-step state, and
    // it must round-trip exactly.
    let topo = Topology::new(2, 2);
    let salt = 0xEF_57A7Eu64;
    let layout = ParamLayout::from_shapes(&[("a".into(), vec![257])]);
    let n = layout.total_len();
    let ranges = bucket_ranges(&build_buckets(&layout, 64));
    let synth = ExactSynth { n, salt };
    let sp = Sparsify::TopK(0.1);

    let uninterrupted = run_pool(topo, n, ranges.clone(), WireFormat::F32,
                                 CommMode::Hierarchical,
                                 IntraNodeMode::Serial, true, 2, 4, sp,
                                 &synth);

    let mut t1 = InProcTransport::new(topo.world_size());
    let mut first = CollectivePool::with_transport(
        topo, n, ranges.clone(), WireFormat::F32, CommMode::Hierarchical,
        IntraNodeMode::Serial, 1 << 16, sp, &mut t1).unwrap();
    for s in 0..2 {
        first.step(&[], 1.0, 2, s, true, &synth).unwrap();
    }
    let snap = first.ef_snapshot();
    assert_eq!(snap.len(), topo.world_size(),
               "active sparsify snapshots one residual per local rank");
    assert!(snap.iter().any(|r| r.iter().any(|&x| x != 0.0)),
            "a lossy ratio must leave real mass in the residual");
    drop(first);

    let mut t2 = InProcTransport::new(topo.world_size());
    let mut resumed = CollectivePool::with_transport(
        topo, n, ranges, WireFormat::F32, CommMode::Hierarchical,
        IntraNodeMode::Serial, 1 << 16, sp, &mut t2).unwrap();
    resumed.restore_ef(&snap).unwrap();
    for s in 2..4 {
        resumed.step(&[], 1.0, 2, s, true, &synth).unwrap();
    }
    let got: Vec<Vec<f32>> = (0..topo.world_size())
        .map(|r| resumed.rank_grads(r).clone())
        .collect();
    assert_bitwise("ef resume vs uninterrupted", &got, &uninterrupted)
        .unwrap();

    // and the guard rails: restoring residuals into a pool whose knob
    // is inert, or the wrong count, fails loudly
    let mut t3 = InProcTransport::new(topo.world_size());
    let dense_pool = CollectivePool::with_transport(
        Topology::new(2, 2), n, bucket_ranges(
            &build_buckets(&ParamLayout::from_shapes(
                &[("a".into(), vec![257])]), 64)),
        WireFormat::F32, CommMode::Hierarchical, IntraNodeMode::Serial,
        1 << 16, Sparsify::None, &mut t3).unwrap();
    let err = dense_pool.restore_ef(&snap).unwrap_err();
    assert!(err.to_string().contains("sparsification is inactive"),
            "{err}");
}

// ---------------------------------------------------------------------------
// determinism across prefetch depths (trainer level, needs artifacts)
// ---------------------------------------------------------------------------

#[test]
fn topk_training_is_bitwise_identical_across_prefetch_depths() {
    // `train.prefetch_depth` changes WHEN batches are staged, never
    // what is computed — and sparsification must not break that: same
    // seed, ratio 0.1, prefetch 0 vs 2, bitwise-identical parameters.
    let Some(art) = artifacts() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    use bertdist::coordinator::prepare_datasets;
    use bertdist::runtime::Engine;
    let dir = testkit::tmp_dir("sparsify_prefetch");
    make_data(dir.path());
    let engine = Engine::cpu(&art).unwrap();
    let datasets = prepare_datasets(dir.path(), 4).unwrap();
    let mut finals: Vec<Vec<f32>> = Vec::new();
    for prefetch in [0usize, 2] {
        let mut cfg = base_cfg("2M2G");
        cfg.train.comm_mode = CommMode::Hierarchical;
        cfg.train.sparsify = Sparsify::TopK(0.1);
        cfg.train.prefetch_depth = prefetch;
        let mut t = bertdist::trainer::Trainer::new(&engine, cfg, 32, 2)
            .unwrap();
        assert!(t.sparsify_active(), "2M2G must activate the sparsifier");
        let r = t.run(&datasets, 4, 4).unwrap();
        assert_eq!(r.steps, 4);
        finals.push(t.params.clone());
    }
    assert_eq!(finals[0].len(), finals[1].len());
    for (i, (a, b)) in finals[0].iter().zip(finals[1].iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(),
                   "param [{i}] diverged across prefetch depths: {a} vs {b}");
    }
}

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn make_data(dir: &std::path::Path) {
    use bertdist::data::corpus::SyntheticCorpus;
    use bertdist::data::{build_shards, Vocab};
    let docs = SyntheticCorpus::new(9, 2_000).documents(24, 8, 10);
    let vocab = Vocab::from_documents(&docs, 512);
    vocab.save(&dir.join("vocab.txt")).unwrap();
    build_shards(&docs, &vocab, 4, dir, "train", 9).unwrap();
}

fn base_cfg(topo: &str) -> bertdist::config::RunConfig {
    let mut cfg = bertdist::config::RunConfig::default();
    cfg.train.preset = "bert-micro".into();
    cfg.train.variant = "fused_f32".into();
    cfg.train.lr = 1e-3;
    cfg.train.warmup_steps = 2;
    cfg.train.accum_steps = 2;
    cfg.train.log_every = 0;
    cfg.cluster.topo = Topology::parse(topo).unwrap();
    cfg
}

// ---------------------------------------------------------------------------
// loud-fail regressions: tampered sparse frames surface named errors
// ---------------------------------------------------------------------------

/// Wraps another transport and tampers with every frame sent on links
/// of one [`LinkKind`] — the desynchronized/buggy peer the sparse
/// protocol checks must catch in release builds.
struct TamperTransport<T: Transport> {
    inner: T,
    kind: LinkKind,
    mutate: fn(&mut Frame),
}

struct TamperTx {
    inner: Box<dyn FrameTx>,
    mutate: fn(&mut Frame),
}

impl FrameTx for TamperTx {
    fn send(&mut self, mut frame: Frame, pool: &mut PayloadPool)
            -> Result<(), TransportError> {
        (self.mutate)(&mut frame);
        self.inner.send(frame, pool)
    }

    fn remote(&self) -> bool {
        self.inner.remote()
    }

    fn take_backpressure_s(&mut self) -> f64 {
        self.inner.take_backpressure_s()
    }
}

impl<T: Transport> Transport for TamperTransport<T> {
    fn world(&self) -> usize {
        self.inner.world()
    }

    fn local_ranks(&self) -> Range<usize> {
        self.inner.local_ranks()
    }

    fn link(&mut self, id: LinkId) -> Result<LinkEnds, TransportError> {
        let mut ends = self.inner.link(id)?;
        if id.kind == self.kind {
            if let Some(tx) = ends.tx.take() {
                ends.tx = Some(Box::new(TamperTx {
                    inner: tx,
                    mutate: self.mutate,
                }));
            }
        }
        Ok(ends)
    }
}

fn skew_sparse_lengths(f: &mut Frame) {
    if let Frame::Sparse { values, .. } = f {
        values.pop();
    }
}

fn oob_sparse_index(f: &mut Frame) {
    if let Frame::Sparse { n, indices, .. } = f {
        if let Some(i) = indices.first_mut() {
            *i = *n; // == segment length: one past the last valid index
        }
    }
}

fn skew_sparse_dim(f: &mut Frame) {
    if let Frame::Sparse { n, .. } = f {
        *n += 1;
    }
}

fn skew_sparse_tag(f: &mut Frame) {
    if let Frame::Sparse { tag, .. } = f {
        *tag += 1;
    }
}

fn wrong_kind_on_sparse_link(f: &mut Frame) {
    if matches!(f, Frame::Sparse { .. }) {
        *f = Frame::Bucket { idx: 0, data: Vec::new() };
    }
}

/// One sparsified pooled step over an in-proc world whose `kind` links
/// tamper with every frame; returns the step error's full message.
fn tampered_step_err(topo: Topology, mode: CommMode, intra: IntraNodeMode,
                     kind: LinkKind, mutate: fn(&mut Frame)) -> String {
    let world = topo.world_size();
    let mut t = TamperTransport {
        inner: InProcTransport::new(world),
        kind,
        mutate,
    };
    let n = 96;
    let ranges = BucketRange::even_split(n, 2);
    let mut pool = CollectivePool::with_transport(
        topo, n, ranges, WireFormat::F32, mode, intra, 1 << 16,
        Sparsify::TopK(0.25), &mut t).unwrap();
    let err = pool
        .step(&[], 1.0, 1, 0, true, &ExactSynth { n, salt: 1 })
        .map(|_| ())
        .unwrap_err();
    format!("{err:#}")
}

#[test]
fn skewed_sparse_index_value_lengths_fail_loudly() {
    // Pre-check, a short value array would silently under-scatter one
    // origin's message.  Cover all three sparse ring links.
    for (topo, mode, intra, kind) in [
        (Topology::new(2, 1), CommMode::Flat, IntraNodeMode::Auto,
         LinkKind::FlatRing),
        (Topology::new(2, 2), CommMode::Hierarchical, IntraNodeMode::Serial,
         LinkKind::LeaderRing),
        (Topology::new(2, 2), CommMode::Hierarchical,
         IntraNodeMode::ReduceScatter, LinkKind::RsCross),
    ] {
        let msg = tampered_step_err(topo, mode, intra, kind,
                                    skew_sparse_lengths);
        assert!(msg.contains("sparse index/value length skew"),
                "{topo} {kind:?}: {msg}");
        assert!(msg.contains("pooled step 0 failed"),
                "{topo} {kind:?}: {msg}");
    }
}

#[test]
fn out_of_bounds_sparse_index_fails_loudly() {
    // An OOB index applied silently would scatter into a NEIGHBORING
    // bucket's sum (or panic on the last one); the receiver must name
    // it before touching the buffer.
    let msg = tampered_step_err(Topology::new(2, 2), CommMode::Hierarchical,
                                IntraNodeMode::Serial, LinkKind::LeaderRing,
                                oob_sparse_index);
    assert!(msg.contains("sparse index out of bounds"), "{msg}");
}

#[test]
fn skewed_sparse_dimension_fails_loudly() {
    let msg = tampered_step_err(Topology::new(2, 1), CommMode::Flat,
                                IntraNodeMode::Auto, LinkKind::FlatRing,
                                skew_sparse_dim);
    assert!(msg.contains("sparse payload dimension skew"), "{msg}");
}

#[test]
fn skewed_sparse_schedule_tag_fails_loudly() {
    let msg = tampered_step_err(Topology::new(2, 2), CommMode::Hierarchical,
                                IntraNodeMode::Serial, LinkKind::LeaderRing,
                                skew_sparse_tag);
    assert!(msg.contains("sparse schedule skew"), "{msg}");
}

#[test]
fn wrong_frame_kind_on_a_sparse_link_fails_loudly() {
    let msg = tampered_step_err(Topology::new(2, 1), CommMode::Flat,
                                IntraNodeMode::Auto, LinkKind::FlatRing,
                                wrong_kind_on_sparse_link);
    assert!(msg.contains("unexpected frame kind on sparse ring link"),
            "{msg}");
}

/// Two socket processes where process 0 tampers its `kind` sends;
/// returns (good process's step error, bad process's step error).
fn socket_tampered_errs(topo: Topology, mode: CommMode,
                        intra: IntraNodeMode, kind: LinkKind,
                        mutate: fn(&mut Frame)) -> (String, String) {
    let peers = probe_addrs(2);
    let world = topo.world_size();
    let n = 96;
    let ranges = BucketRange::even_split(n, 2);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..2)
            .map(|p| {
                let peers = peers.clone();
                let ranges = ranges.clone();
                scope.spawn(move || {
                    let mut sock = SocketTransport::with_hosts(
                        world, &peers[p], peers.clone(), 30.0).unwrap();
                    let err = if p == 0 {
                        let mut t = TamperTransport {
                            inner: sock,
                            kind,
                            mutate,
                        };
                        let mut pool = CollectivePool::with_transport(
                            topo, n, ranges, WireFormat::F32, mode, intra,
                            1 << 16, Sparsify::TopK(0.25), &mut t).unwrap();
                        pool.step(&[], 1.0, 1, 0, true,
                                  &ExactSynth { n, salt: 1 })
                            .map(|_| ())
                            .unwrap_err()
                    } else {
                        let mut pool = CollectivePool::with_transport(
                            topo, n, ranges, WireFormat::F32, mode, intra,
                            1 << 16, Sparsify::TopK(0.25), &mut sock)
                            .unwrap();
                        pool.step(&[], 1.0, 1, 0, true,
                                  &ExactSynth { n, salt: 1 })
                            .map(|_| ())
                            .unwrap_err()
                    };
                    format!("{err:#}")
                })
            })
            .collect();
        let mut msgs = handles
            .into_iter()
            .map(|h| h.join().expect("socket thread panicked"));
        let bad = msgs.next().unwrap();
        let good = msgs.next().unwrap();
        (good, bad)
    })
}

#[test]
fn truncated_sparse_payload_fails_loudly_over_sockets() {
    // Over the wire the entry COUNT is the single source of truth for
    // the body length: popping a value off the frame ships a body 4
    // bytes short of its count, and the v1 codec must refuse it by
    // name before recv_sparse ever sees it.
    let (good, _bad) = socket_tampered_errs(
        Topology::new(2, 2), CommMode::Hierarchical, IntraNodeMode::Serial,
        LinkKind::LeaderRing, skew_sparse_lengths);
    assert!(good.contains("sparse payload truncated or skewed"), "{good}");
}

#[test]
fn out_of_bounds_sparse_index_fails_loudly_over_sockets() {
    // An OOB index survives the codec (the bytes are well-formed) and
    // must be caught by the shared recv_sparse bounds check instead.
    let (good, _bad) = socket_tampered_errs(
        Topology::new(2, 2), CommMode::Hierarchical, IntraNodeMode::Serial,
        LinkKind::LeaderRing, oob_sparse_index);
    assert!(good.contains("sparse index out of bounds"), "{good}");
}

#[test]
fn skewed_sparse_lengths_fail_loudly_on_the_rs_cross_ring_over_sockets() {
    // The rs schedule's cross-machine shard rings carry sparse frames
    // too; the 2M2G machine-per-process split sends them over real
    // sockets.
    let (good, _bad) = socket_tampered_errs(
        Topology::new(2, 2), CommMode::Hierarchical,
        IntraNodeMode::ReduceScatter, LinkKind::RsCross,
        skew_sparse_lengths);
    assert!(good.contains("sparse payload truncated or skewed"), "{good}");
}
